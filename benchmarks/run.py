"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = 0 for modeled
or dimensionless rows).  An optional LM-roofline summary is appended when
dry-run artifacts exist under experiments/dryrun/.

Run:  PYTHONPATH=src python -m benchmarks.run

``--smoke`` runs a CI-sized subset instead (tiny grid, a few steps, all
three backends incl. pallas interpret) and writes the rows to a
``BENCH_*.json`` artifact so the perf trajectory accumulates per commit.

``--tune`` runs the measured plan search (repro.core.tune) on the same
CI-sized problem and emits tuned-vs-``auto_plan`` rows per backend, so the
artifact trail records the tuner's wins per commit; the winning plans are
persisted to the JSON plan cache at ``--plan-cache``.

``--mesh AxB`` (with ``--smoke``) additionally runs the *sharded* fused
loop — ``compile_program(..., mesh=, steps=N)`` with carry-resident halo
exchange — over a simulated AxB device mesh and emits sharded steps/sec
rows into the same artifact.  On CPU hosts the required device count is
simulated automatically via ``--xla_force_host_platform_device_count``.
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import platform
import sys
import time


def _parse_mesh(val: str) -> tuple:
    try:
        shape = tuple(int(v) for v in val.split("x"))
    except ValueError:
        raise SystemExit(f"run.py: error: --mesh must be AxB (or AxBxC), "
                         f"got {val!r}")
    if not shape or any(s < 1 for s in shape):
        raise SystemExit(f"run.py: error: --mesh axes must be >= 1, "
                         f"got {val!r}")
    return shape


def _mesh_arg(argv) -> tuple | None:
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return _parse_mesh(argv[i + 1])
        if a.startswith("--mesh="):
            return _parse_mesh(a.split("=", 1)[1])
    return None


# honour --mesh before anything imports jax: simulated CPU devices can only
# be configured through XLA_FLAGS at process start (append to any existing
# flags; an explicit device-count override wins)
_MESH_SHAPE = _mesh_arg(sys.argv)
if _MESH_SHAPE and ("--xla_force_host_platform_device_count"
                    not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " "
        + "--xla_force_host_platform_device_count="
        + str(math.prod(_MESH_SHAPE))).strip()

try:
    from benchmarks import fig4_throughput, fig5_6_energy, tab1_2_resources
except ModuleNotFoundError:  # invoked as `python benchmarks/run.py`
    import fig4_throughput
    import fig5_6_energy
    import tab1_2_resources


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}", flush=True)


def run_smoke(out_path: str, mesh_shape: tuple | None = None,
              baseline_path: str | None = None) -> None:
    """Tiny fused-loop benchmark (16^3, 3 steps, interpret mode) -> JSON.

    With ``mesh_shape`` the sharded fused loop (one dispatch, ppermute
    halo exchange inside the carry) runs over a simulated device mesh and
    contributes ``dist/...`` steps/sec rows to the artifact.  With
    ``baseline_path`` the compute rows are gated against the committed
    baseline (see :func:`check_smoke_baseline`)."""
    rows = []

    def emit_row(name: str, us: float, derived: str = "", **extra):
        emit(name, us, derived)
        row = {"name": name, "us": round(us, 2), "derived": derived}
        row.update({k: v for k, v in extra.items() if v is not None})
        rows.append(row)

    grid, steps = (16, 16, 16), 3
    fig4_throughput.run_fused_loop(
        emit_row, grid=grid, steps=steps,
        backends=("jnp_naive", "jnp_fused", "pallas"))
    run_schedule_rows(emit_row, grid=grid, steps=steps)
    if mesh_shape:
        run_sharded_loop(emit_row, grid=grid, steps=steps,
                         mesh_shape=mesh_shape)
        run_stream_mesh_rows(emit_row, grid=grid, steps=steps,
                             mesh_shape=mesh_shape)
    doc = {
        "kind": "bench_smoke",
        "grid": list(grid),
        "steps": steps,
        "mesh": list(mesh_shape) if mesh_shape else None,
        "time": time.time(),
        "platform": platform.platform(),
        "commit": os.environ.get("GITHUB_SHA", ""),
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows)", flush=True)
    if baseline_path:
        check_smoke_baseline(rows, baseline_path)


def check_smoke_baseline(rows: list, baseline_path: str) -> None:
    """Compute-row regression gate, mirroring the ``--serve`` one: every
    ``steps_per_sec`` row in the committed baseline must appear in the
    smoke artifact at no less than ``baseline * (1 - tolerance)`` steps/sec.
    A baseline row missing from the artifact fails too — a silently renamed
    or dropped row must not read as a pass."""
    if not os.path.exists(baseline_path):
        print(f"smoke baseline {baseline_path} missing; gate skipped",
              flush=True)
        return
    base = json.load(open(baseline_path))
    tol = float(base.get("tolerance", 0.30))
    measured = {}
    for row in rows:
        derived = row.get("derived", "")
        if derived.endswith("steps/s"):
            measured[row["name"]] = float(derived.split()[0])
    failures = []
    for name, floor_sps in base.get("steps_per_sec", {}).items():
        floor = float(floor_sps) * (1.0 - tol)
        got = measured.get(name)
        if got is None:
            failures.append(f"  {name}: row missing from artifact")
        elif got < floor:
            failures.append(f"  {name}: {got:.2f} steps/s < {floor:.2f} "
                            f"floor (baseline {float(floor_sps):.2f} "
                            f"- {tol:.0%})")
    # roofline-achieved floors WARN, never fail: the fraction on CI hosts
    # is noisy (interpret mode on shared CPU vs a TPU-priced model), so a
    # dip is a flag for a human, not a red build — ROADMAP item 3 hardens
    # this into a gate once the trend stabilises
    fractions = {row["name"]: row["roofline_fraction"] for row in rows
                 if "roofline_fraction" in row}
    for name, floor in base.get("roofline_floor", {}).items():
        got = fractions.get(name)
        if got is None:
            print(f"WARNING: {name}: no roofline_fraction in artifact "
                  f"(floor {float(floor):.2e})", flush=True)
        elif got < float(floor):
            print(f"WARNING: {name}: roofline_fraction {got:.2e} below "
                  f"floor {float(floor):.2e} (achieved share of the model "
                  "prediction dropped — not failing the build)", flush=True)
    if failures:
        raise SystemExit("smoke compute-row regression:\n"
                         + "\n".join(failures))
    print(f"smoke baseline check OK: {len(base.get('steps_per_sec', {}))} "
          f"rows within {tol:.0%} of {baseline_path}", flush=True)


def run_schedule_rows(emit_row, grid: tuple, steps: int) -> None:
    """Stream-vs-block schedule rows: fused-loop steps/sec of the pallas
    shift-register sweep (each input plane fetched once, windows in the
    kernel carry) next to the tiled block schedule, so the artifact trail
    records the dataflow layer's trajectory per commit.  Inputs come from
    ``fig4_throughput._data`` so these rows are directly comparable to the
    adjacent ``fig4/.../fused_loop`` rows in the same artifact."""
    import jax
    from repro.apps import pw_advection, pw_advection_update
    from repro.core import CompileOptions, compile_program
    from repro.obs.achieved import fraction_for

    p = pw_advection()
    update = pw_advection_update(0.1)
    tag = "x".join(str(g) for g in grid)
    fields, scalars, coeffs = fig4_throughput._data(p, grid)

    def measure(opts, nsteps):
        """Best-of-3 seconds per call plus the roofline-achieved fraction
        (measured vs model_plan prediction — tiny under CPU interpret, the
        per-commit *trend* is what the baseline floor watches)."""
        exN = compile_program(p, grid, options=opts)
        jax.block_until_ready(exN(fields, scalars, coeffs)["u"])
        dt = float("inf")
        for _ in range(3):                      # best-of-3 (CPU noise)
            t0 = time.perf_counter()
            out = exN(fields, scalars, coeffs)
            jax.block_until_ready(out["u"])
            dt = min(dt, time.perf_counter() - t0)
        return dt, fraction_for(exN, dt)

    sps = {}
    for schedule in ("block", "stream"):
        dt, rf = measure(CompileOptions(backend="pallas", steps=steps,
                                        update=update, schedule=schedule),
                         steps)
        sps[schedule] = steps / dt
        emit_row(f"sched/pw_advection/{tag}/pallas/{schedule}/fused_loop",
                 dt * 1e6, f"{steps / dt:.2f} steps/s",
                 roofline_fraction=rf)
    emit_row(f"sched/pw_advection/{tag}/pallas/stream_vs_block", 0.0,
             f"{sps['stream'] / sps['block']:.2f}x stream vs block")

    # temporal blocking through the stream sweep: T=4 chains four time
    # steps per sweep (inputs fetched from HBM once per 4 steps), T=1 is
    # the unchained baseline at the same step count
    tsteps = max(steps, 4)
    tiled = {}
    for tt in (1, 4):
        dt, rf = measure(CompileOptions(backend="pallas", steps=tsteps,
                                        update=update, schedule="stream",
                                        time_tile=tt), tsteps)
        tiled[tt] = tsteps / dt
        emit_row(f"sched/pw_advection/{tag}/pallas/stream/time_tile={tt}"
                 f"/fused_loop", dt * 1e6, f"{tsteps / dt:.2f} steps/s",
                 roofline_fraction=rf)
    emit_row(f"sched/pw_advection/{tag}/pallas/stream/t4_vs_t1", 0.0,
             f"{tiled[4] / tiled[1]:.2f}x time_tile=4 vs 1")

    # spatial x temporal tile matrix: plane_tile=P advances P planes per
    # sweep grid step (amortising per-step dispatch/window-shift overhead),
    # composing with the T-deep temporal chain into one PxT tile
    matrix = {}
    for pt in (1, 4):
        for tt in (1, 4):
            dt, rf = measure(CompileOptions(backend="pallas", steps=tsteps,
                                            update=update, schedule="stream",
                                            time_tile=tt, plane_tile=pt),
                             tsteps)
            matrix[pt, tt] = tsteps / dt
            emit_row(f"sched/pw_advection/{tag}/pallas/stream"
                     f"/plane_tile={pt}/time_tile={tt}/fused_loop",
                     dt * 1e6, f"{tsteps / dt:.2f} steps/s",
                     roofline_fraction=rf)
    emit_row(f"sched/pw_advection/{tag}/pallas/stream/p4_vs_p1", 0.0,
             f"{matrix[4, 1] / matrix[1, 1]:.2f}x plane_tile=4 vs 1")


def run_sharded_loop(emit_row, grid: tuple, steps: int,
                     mesh_shape: tuple) -> None:
    """Sharded fused-loop rows: steps/sec of N distributed steps in one
    jitted dispatch, zero and periodic boundaries."""
    import jax
    import numpy as np
    from repro.apps import pw_advection, pw_advection_update
    from repro.core import compile_program
    from repro.dist.sharding import make_auto_mesh

    names = ("X", "Y", "Z")[:len(mesh_shape)]
    mesh = make_auto_mesh(mesh_shape, names)
    update = pw_advection_update(0.1)
    tag = "x".join(str(g) for g in grid)
    mtag = "x".join(str(m) for m in mesh_shape)
    rng = np.random.default_rng(0)
    fields = {f: rng.normal(size=grid).astype(np.float32)
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    for boundary in ("zero", "periodic"):
        p = pw_advection(boundary=boundary)
        for backend in ("jnp_fused", "pallas"):
            exN = compile_program(p, grid, backend=backend, mesh=mesh,
                                  mesh_axes=names, steps=steps,
                                  update=update)
            jax.block_until_ready(exN(fields, scalars, coeffs)["u"])
            dt = float("inf")
            for _ in range(3):                  # best-of-3 (CPU noise)
                t0 = time.perf_counter()
                out = exN(fields, scalars, coeffs)
                jax.block_until_ready(out["u"])
                dt = min(dt, time.perf_counter() - t0)
            emit_row(
                f"dist/pw_advection/{tag}/mesh{mtag}/{boundary}/{backend}"
                "/fused_loop",
                dt * 1e6, f"{steps / dt:.2f} steps/s "
                          f"local={exN.shard.local_grid}")


def run_stream_mesh_rows(emit_row, grid: tuple, steps: int,
                         mesh_shape: tuple) -> None:
    """Stream-schedule-under-mesh rows: each shard sweeps the stream axis
    over its local block with halo refresh inside the fused-loop carry.
    Emits steps/sec for time_tile 1 and 2 plus the stream-vs-block ratio
    on the same mesh (the block number is measured here, same data and
    discipline as ``run_sharded_loop``, so the ratio is apples-to-apples)."""
    import jax
    import numpy as np
    from repro.apps import pw_advection, pw_advection_update
    from repro.core import CompileOptions, compile_program
    from repro.dist.sharding import make_auto_mesh

    names = ("X", "Y", "Z")[:len(mesh_shape)]
    mesh = make_auto_mesh(mesh_shape, names)
    update = pw_advection_update(0.1)
    tag = "x".join(str(g) for g in grid)
    mtag = "x".join(str(m) for m in mesh_shape)
    p = pw_advection()
    rng = np.random.default_rng(0)
    fields = {f: rng.normal(size=grid).astype(np.float32)
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}

    def measure(schedule, time_tile=None):
        exN = compile_program(p, grid, options=CompileOptions(
            backend="pallas", steps=steps, update=update, schedule=schedule,
            time_tile=time_tile, mesh=mesh, mesh_axes=names))
        jax.block_until_ready(exN(fields, scalars, coeffs)["u"])
        dt = float("inf")
        for _ in range(3):                      # best-of-3 (CPU noise)
            t0 = time.perf_counter()
            out = exN(fields, scalars, coeffs)
            jax.block_until_ready(out["u"])
            dt = min(dt, time.perf_counter() - t0)
        return dt

    sps = {}
    for schedule in ("block", "stream"):
        dt = measure(schedule)
        sps[schedule] = steps / dt
        emit_row(f"sched/pw_advection/{tag}/pallas/{schedule}/mesh={mtag}"
                 "/fused_loop", dt * 1e6, f"{steps / dt:.2f} steps/s")
    emit_row(f"sched/pw_advection/{tag}/pallas/mesh={mtag}/stream_vs_block",
             0.0, f"{sps['stream'] / sps['block']:.2f}x stream vs block "
                  "under mesh")
    dt = measure("stream", time_tile=2)
    emit_row(f"sched/pw_advection/{tag}/pallas/stream/mesh={mtag}"
             "/time_tile=2/fused_loop", dt * 1e6,
             f"{steps / dt:.2f} steps/s")


def run_tune(out_path: str, cache_path: str) -> None:
    """Measured plan search on the smoke problem (16^3 x 3 steps, all three
    backends, pruned candidate set) -> tuned-vs-auto_plan rows + plan cache."""
    from repro.apps import pw_advection, pw_advection_update
    from repro.core import tune_plan, TuneConfig, PlanCache

    grid, steps = (16, 16, 16), 3
    p = pw_advection()
    cfg = TuneConfig(steps=steps, repeats=2, max_measured=4)
    cache = PlanCache(path=cache_path)
    tag = "x".join(map(str, grid))
    rows = []

    def emit_row(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        rows.append({"name": name, "us": round(us, 2), "derived": derived})

    for backend in ("jnp_naive", "jnp_fused", "pallas"):
        res = tune_plan(p, grid, backend=backend,
                        update=pw_advection_update(0.1), config=cfg,
                        cache=cache)
        base = res.baseline
        emit_row(f"tune/{p.name}/{tag}/{backend}/auto_plan",
                 base.us_fused, f"{steps / (base.us_fused * 1e-6):.2f} steps/s")
        emit_row(f"tune/{p.name}/{tag}/{backend}/tuned",
                 res.record["us_fused"],
                 f"{steps / (res.record['us_fused'] * 1e-6):.2f} steps/s "
                 f"[{res.record['label']}]")
        emit_row(f"tune/{p.name}/{tag}/{backend}/speedup", 0.0,
                 f"{base.us_fused / res.record['us_fused']:.2f}x tuned vs "
                 f"auto_plan ({res.record['measured']} of "
                 f"{res.record['candidates']} candidates measured)")
    doc = {
        "kind": "bench_tune",
        "grid": list(grid),
        "steps": steps,
        "time": time.time(),
        "platform": platform.platform(),
        "commit": os.environ.get("GITHUB_SHA", ""),
        "plan_cache": cache_path,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows); plan cache -> {cache_path}",
          flush=True)


def run_serve(out_path: str, baseline_path: str | None = None) -> None:
    """Serving-layer smoke: mixed-shape request traffic through the async
    StencilEngine -> throughput + latency-quantile rows, plus a regression
    gate against the committed baseline (fail when throughput drops more
    than the baseline's tolerance, default 30%)."""
    import numpy as np
    from repro.apps import pw_advection, pw_advection_update
    from repro.serve import StencilEngine, StencilRequest

    steps, rounds = 3, 6
    p = pw_advection()
    update = pw_advection_update(0.1)
    grids = [(16, 16, 16), (12, 14, 16), (16, 16, 24), (10, 16, 16)]
    rng = np.random.default_rng(0)

    def make_req(grid):
        fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
                  for f in ("u", "v", "w")}
        scalars = {"tcx": 0.05, "tcy": 0.05}
        coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
                  for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
        return StencilRequest(program=p, fields=fields, scalars=scalars,
                              coeffs=coeffs, steps=steps, update=update,
                              update_key="pw/dt=0.1")

    rows = []

    def emit_row(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        rows.append({"name": name, "us": round(us, 2), "derived": derived})

    with StencilEngine(backend="jnp_fused", max_batch=4,
                       window_s=0.005) as eng:
        # warm phase: compile every bucket once
        eng.map([make_req(g) for g in grids], timeout=600)
        warm_traces = eng.stats.traces
        eng.stats.reset_latencies()   # quantiles = steady state, not compiles
        t0 = time.perf_counter()
        futs = [eng.submit(make_req(g))
                for _ in range(rounds) for g in grids]
        for f in futs:
            f.result(600)
        wall = time.perf_counter() - t0
        s = eng.stats
        tput = len(futs) / wall
        tag = f"pw_advection/jnp_fused/steps{steps}"
        emit_row(f"serve/{tag}/throughput", 0.0,
                 f"{tput:.2f} req/s ({len(futs)} reqs in {wall:.2f}s)")
        emit_row(f"serve/{tag}/p50", s.p50_ms() * 1e3,
                 f"{s.p50_ms():.1f} ms")
        emit_row(f"serve/{tag}/p99", s.p99_ms() * 1e3,
                 f"{s.p99_ms():.1f} ms")
        emit_row(f"serve/{tag}/cache", 0.0,
                 f"hit_rate={s.cache_hit_rate():.2f} "
                 f"occupancy={s.occupancy():.2f} "
                 f"warm_traces={s.traces - warm_traces} "
                 f"compiles={s.compiles}")
        summary = {"throughput_rps": tput, "p50_ms": s.p50_ms(),
                   "p99_ms": s.p99_ms(), "hit_rate": s.cache_hit_rate(),
                   "occupancy": s.occupancy(),
                   "warm_traces": s.traces - warm_traces}
    doc = {
        "kind": "bench_serve_smoke",
        "grids": [list(g) for g in grids],
        "steps": steps,
        "requests": rounds * len(grids),
        "time": time.time(),
        "platform": platform.platform(),
        "commit": os.environ.get("GITHUB_SHA", ""),
        "summary": summary,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows)", flush=True)
    if summary["warm_traces"]:
        raise SystemExit(f"serve smoke: {summary['warm_traces']} re-traces "
                         "on warm requests (expected 0)")
    if baseline_path and os.path.exists(baseline_path):
        base = json.load(open(baseline_path))
        tol = float(base.get("tolerance", 0.30))
        floor = float(base["throughput_rps"]) * (1.0 - tol)
        if tput < floor:
            raise SystemExit(
                f"serve throughput regression: {tput:.2f} req/s < "
                f"{floor:.2f} req/s floor (baseline "
                f"{base['throughput_rps']:.2f} req/s - {tol:.0%})")
        print(f"serve baseline check OK: {tput:.2f} req/s >= "
              f"{floor:.2f} req/s floor", flush=True)


def lm_roofline_summary(emit):
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 r.get("status", "?"))
            continue
        t = r["roofline"].get("terms_primary",
                              r["roofline"]["terms_corrected"])
        emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"dom={t['dominant']} compute={t['compute_s']:.3e}s "
             f"memory={t['memory_s']:.3e}s coll={t['collective_s']:.3e}s "
             f"mem/dev={r['memory']['per_device_total']/2**30:.2f}GiB")


def main() -> None:
    # no prefix abbreviation: the import-time _mesh_arg scanner (which sized
    # the simulated device count before jax loaded) only matches the full
    # --mesh spelling, and the two must never diverge
    ap = argparse.ArgumentParser(description=__doc__, allow_abbrev=False)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fused-loop benchmark, writes a JSON "
                         "artifact instead of the full paper sweep")
    ap.add_argument("--tune", action="store_true",
                    help="CI-sized measured plan search: tuned-vs-auto_plan "
                         "rows per backend + persistent plan cache")
    ap.add_argument("--serve", action="store_true",
                    help="serving-layer smoke: mixed-shape traffic through "
                         "the async StencilEngine, throughput + p50/p99 "
                         "rows, baseline regression gate")
    ap.add_argument("--serve-baseline",
                    default="benchmarks/serve_baseline.json",
                    help="baseline JSON for the --serve regression gate "
                         "(missing file skips the gate)")
    ap.add_argument("--smoke-baseline", default=None,
                    help="baseline JSON for the --smoke compute-row "
                         "regression gate (omit to skip; simulated-mesh "
                         "runs skew timings, so the CI gate only arms the "
                         "unmeshed smoke job)")
    ap.add_argument("--out", default=None,
                    help="artifact path for --smoke / --tune / --serve "
                         "(default BENCH_smoke.json / BENCH_tune_smoke.json "
                         "/ BENCH_serve_smoke.json)")
    ap.add_argument("--plan-cache", default="PLAN_CACHE_smoke.json",
                    help="plan-cache path for --tune")
    ap.add_argument("--mesh", default=None,
                    help="AxB (or AxBxC) device mesh: adds sharded "
                         "fused-loop steps/sec rows to the --smoke "
                         "artifact (CPU devices simulated automatically)")
    args = ap.parse_args()
    # reuse the shape parsed at import time (it sized the simulated device
    # count) rather than re-parsing args.mesh — one parser, no drift
    mesh_shape = _MESH_SHAPE
    want = (tuple(int(v) for v in args.mesh.split("x"))
            if args.mesh else None)
    if want != mesh_shape:
        ap.error(f"--mesh mismatch: argparse saw {want}, the import-time "
                 f"scanner saw {mesh_shape}")
    if mesh_shape and (args.tune or args.serve or not args.smoke):
        ap.error("--mesh only applies to --smoke (the XLA device-count "
                 "override would silently skew --tune / --serve / "
                 "full-sweep timings)")

    emit("bench/header", 0.0, "name,us_per_call,derived")
    if args.tune:
        run_tune(args.out or "BENCH_tune_smoke.json", args.plan_cache)
        return
    if args.serve:
        run_serve(args.out or "BENCH_serve_smoke.json", args.serve_baseline)
        return
    if args.smoke:
        run_smoke(args.out or "BENCH_smoke.json", mesh_shape=mesh_shape,
                  baseline_path=args.smoke_baseline)
        return
    fig4_throughput.run(emit)
    fig5_6_energy.run(emit)
    tab1_2_resources.run(emit)
    if glob.glob("experiments/dryrun/*.json"):
        lm_roofline_summary(emit)


if __name__ == "__main__":
    main()
