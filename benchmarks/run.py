"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = 0 for modeled
or dimensionless rows).  An optional LM-roofline summary is appended when
dry-run artifacts exist under experiments/dryrun/.

Run:  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import glob
import json

from benchmarks import fig4_throughput, fig5_6_energy, tab1_2_resources


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}", flush=True)


def lm_roofline_summary(emit):
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 r.get("status", "?"))
            continue
        t = r["roofline"].get("terms_primary",
                              r["roofline"]["terms_corrected"])
        emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"dom={t['dominant']} compute={t['compute_s']:.3e}s "
             f"memory={t['memory_s']:.3e}s coll={t['collective_s']:.3e}s "
             f"mem/dev={r['memory']['per_device_total']/2**30:.2f}GiB")


def main() -> None:
    emit("bench/header", 0.0, "name,us_per_call,derived")
    fig4_throughput.run(emit)
    fig5_6_energy.run(emit)
    tab1_2_resources.run(emit)
    if glob.glob("experiments/dryrun/*.json"):
        lm_roofline_summary(emit)


if __name__ == "__main__":
    main()
