"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = 0 for modeled
or dimensionless rows).  An optional LM-roofline summary is appended when
dry-run artifacts exist under experiments/dryrun/.

Run:  PYTHONPATH=src python -m benchmarks.run

``--smoke`` runs a CI-sized subset instead (tiny grid, a few steps, all
three backends incl. pallas interpret) and writes the rows to a
``BENCH_*.json`` artifact so the perf trajectory accumulates per commit.

``--tune`` runs the measured plan search (repro.core.tune) on the same
CI-sized problem and emits tuned-vs-``auto_plan`` rows per backend, so the
artifact trail records the tuner's wins per commit; the winning plans are
persisted to the JSON plan cache at ``--plan-cache``.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import platform
import time

try:
    from benchmarks import fig4_throughput, fig5_6_energy, tab1_2_resources
except ModuleNotFoundError:  # invoked as `python benchmarks/run.py`
    import fig4_throughput
    import fig5_6_energy
    import tab1_2_resources


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}", flush=True)


def run_smoke(out_path: str) -> None:
    """Tiny fused-loop benchmark (16^3, 3 steps, interpret mode) -> JSON."""
    rows = []

    def emit_row(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        rows.append({"name": name, "us": round(us, 2), "derived": derived})

    fig4_throughput.run_fused_loop(
        emit_row, grid=(16, 16, 16), steps=3,
        backends=("jnp_naive", "jnp_fused", "pallas"))
    doc = {
        "kind": "bench_smoke",
        "grid": [16, 16, 16],
        "steps": 3,
        "time": time.time(),
        "platform": platform.platform(),
        "commit": os.environ.get("GITHUB_SHA", ""),
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows)", flush=True)


def run_tune(out_path: str, cache_path: str) -> None:
    """Measured plan search on the smoke problem (16^3 x 3 steps, all three
    backends, pruned candidate set) -> tuned-vs-auto_plan rows + plan cache."""
    from repro.apps import pw_advection, pw_advection_update
    from repro.core import tune_plan, TuneConfig, PlanCache

    grid, steps = (16, 16, 16), 3
    p = pw_advection()
    cfg = TuneConfig(steps=steps, repeats=2, max_measured=4)
    cache = PlanCache(path=cache_path)
    tag = "x".join(map(str, grid))
    rows = []

    def emit_row(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        rows.append({"name": name, "us": round(us, 2), "derived": derived})

    for backend in ("jnp_naive", "jnp_fused", "pallas"):
        res = tune_plan(p, grid, backend=backend,
                        update=pw_advection_update(0.1), config=cfg,
                        cache=cache)
        base = res.baseline
        emit_row(f"tune/{p.name}/{tag}/{backend}/auto_plan",
                 base.us_fused, f"{steps / (base.us_fused * 1e-6):.2f} steps/s")
        emit_row(f"tune/{p.name}/{tag}/{backend}/tuned",
                 res.record["us_fused"],
                 f"{steps / (res.record['us_fused'] * 1e-6):.2f} steps/s "
                 f"[{res.record['label']}]")
        emit_row(f"tune/{p.name}/{tag}/{backend}/speedup", 0.0,
                 f"{base.us_fused / res.record['us_fused']:.2f}x tuned vs "
                 f"auto_plan ({res.record['measured']} of "
                 f"{res.record['candidates']} candidates measured)")
    doc = {
        "kind": "bench_tune",
        "grid": list(grid),
        "steps": steps,
        "time": time.time(),
        "platform": platform.platform(),
        "commit": os.environ.get("GITHUB_SHA", ""),
        "plan_cache": cache_path,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows); plan cache -> {cache_path}",
          flush=True)


def lm_roofline_summary(emit):
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 r.get("status", "?"))
            continue
        t = r["roofline"].get("terms_primary",
                              r["roofline"]["terms_corrected"])
        emit(f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"dom={t['dominant']} compute={t['compute_s']:.3e}s "
             f"memory={t['memory_s']:.3e}s coll={t['collective_s']:.3e}s "
             f"mem/dev={r['memory']['per_device_total']/2**30:.2f}GiB")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fused-loop benchmark, writes a JSON "
                         "artifact instead of the full paper sweep")
    ap.add_argument("--tune", action="store_true",
                    help="CI-sized measured plan search: tuned-vs-auto_plan "
                         "rows per backend + persistent plan cache")
    ap.add_argument("--out", default=None,
                    help="artifact path for --smoke / --tune "
                         "(default BENCH_smoke.json / BENCH_tune_smoke.json)")
    ap.add_argument("--plan-cache", default="PLAN_CACHE_smoke.json",
                    help="plan-cache path for --tune")
    args = ap.parse_args()

    emit("bench/header", 0.0, "name,us_per_call,derived")
    if args.tune:
        run_tune(args.out or "BENCH_tune_smoke.json", args.plan_cache)
        return
    if args.smoke:
        run_smoke(args.out or "BENCH_smoke.json")
        return
    fig4_throughput.run(emit)
    fig5_6_energy.run(emit)
    tab1_2_resources.run(emit)
    if glob.glob("experiments/dryrun/*.json"):
        lm_roofline_summary(emit)


if __name__ == "__main__":
    main()
