"""Paper Tables 1/2: resource utilisation analogue.

FPGA resources map to TPU budgets as:
    %BRAM  -> VMEM window bytes per kernel instance / 128 MiB
    %LUT/FF-> (no analogue: Mosaic owns logic; we report kernel count)
    AXI ports / HBM banks -> field inputs per fuse group (memory streams)
    BRAM growth with problem size -> coefficient ('small data') bytes

Derived from the actual compiled plans, per problem size.
"""

from __future__ import annotations


from repro import hw
from repro.apps import pw_advection, tracer_advection
from repro.core.passes import infer_halo
from repro.core.schedule import auto_plan, vmem_cost

SIZES = {
    "8M": (256, 256, 128),
    "32M": (512, 256, 256),
    "134M": (1024, 512, 256),
}


def run(emit):
    for prog_fn in (pw_advection, tracer_advection):
        p = prog_fn()
        for size, grid in SIZES.items():
            if p.name == "tracer_advection" and size == "134M":
                continue
            plan = auto_plan(p, grid)
            vmem = vmem_cost(p, plan, grid)
            pct = 100.0 * vmem / hw.TPU_V5E.vmem_bytes
            ports = max(len(infer_halo(p, g).group_inputs)
                        + len(infer_halo(p, g).group_outputs)
                        for g in plan.groups)
            coeff_bytes = sum(grid[ax] * 4 for _, ax in p.coeffs.items())
            emit(f"tab1_2/{p.name}/{size}/vmem_pct", 0.0,
                 f"{pct:.2f}% of VMEM ({vmem/2**20:.2f} MiB, "
                 f"block={plan.block}, groups={len(plan.groups)})")
            emit(f"tab1_2/{p.name}/{size}/stream_ports", 0.0,
                 f"{ports} field streams in widest group "
                 f"(paper: 7 AXI ports/CU for PW)")
            emit(f"tab1_2/{p.name}/{size}/small_data_bytes", 0.0,
                 f"{coeff_bytes} B coeff arrays (grows with nz, "
                 f"paper: BRAM grows with size)")
