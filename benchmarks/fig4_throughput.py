"""Paper Fig. 4: stencil throughput (MPt/s) across 'frameworks'.

Framework role mapping (DESIGN.md §7):
    jnp_naive  -> unoptimised Vitis HLS / -O0 (no reuse structure)
    jnp_fused  -> DaCe (optimising, not stencil-specialised)
    pallas     -> Stencil-HMLS (this work): generated dataflow kernels

Two number sets, clearly labelled:
  * measured — wall-clock on this CPU container (jnp backends; the pallas
    interpreter is a correctness tool, not a performance proxy)
  * modeled  — TPU v5e roofline MPt/s per backend from the streaming model
    (analysis.stencil_roofline), the apples-to-apples Fig.4 analogue
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.stencil_roofline import model_program
from repro.apps import pw_advection, pw_advection_update, tracer_advection
from repro.core import compile_program, run_time_loop

# paper sizes: 8M / 32M points (134M is modeled only on this container)
SIZES = {
    "8M": (256, 256, 128),
    "32M": (512, 256, 256),
}
MODEL_ONLY_SIZES = {"134M": (1024, 512, 256)}

# fused-vs-host time-loop comparison (the PR's steps/sec headline number)
FUSED_GRID = (64, 64, 128)
FUSED_STEPS = 10


def _data(p, grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32))
              for f in p.input_fields()}
    if "e3t" in fields:
        fields["e3t"] = jnp.abs(fields["e3t"]) + 1.0
    if "msk" in fields:
        fields["msk"] = (fields["msk"] > 0).astype(jnp.float32)
    scalars = {s: jnp.float32(0.1) for s in p.scalars}
    coeffs = {c: jnp.asarray(rng.normal(size=(grid[ax],)).astype(np.float32))
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


def _time(fn, *args, iters=3):
    fn(*args)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(emit):
    for prog_fn in (pw_advection, tracer_advection):
        p = prog_fn()
        model = model_program(p)
        for size, grid in SIZES.items():
            pts = float(np.prod(grid))
            fields, scalars, coeffs = _data(p, grid)
            for backend in ("jnp_naive", "jnp_fused"):
                ex = compile_program(p, grid, backend=backend)
                dt = _time(ex, fields, scalars, coeffs)
                emit(f"fig4/{p.name}/{size}/{backend}/measured_cpu",
                     dt * 1e6, f"{pts / dt / 1e6:.1f} MPt/s")
            for backend in ("jnp_naive", "jnp_fused", "pallas"):
                mp = model.mpts(backend)
                emit(f"fig4/{p.name}/{size}/{backend}/modeled_v5e",
                     pts / (mp * 1e6) * 1e6, f"{mp:.1f} MPt/s")
        for size, grid in MODEL_ONLY_SIZES.items():
            pts = float(np.prod(grid))
            for backend in ("jnp_naive", "jnp_fused", "pallas"):
                mp = model.mpts(backend)
                emit(f"fig4/{p.name}/{size}/{backend}/modeled_v5e",
                     pts / (mp * 1e6) * 1e6, f"{mp:.1f} MPt/s")
        # the paper's headline ratio: ours vs next-best automated tool
        ratio = model.mpts("pallas") / model.mpts("jnp_fused")
        emit(f"fig4/{p.name}/speedup_vs_next_best", 0.0,
             f"{ratio:.1f}x modeled (paper: 14-100x vs DaCe)")


def run_fused_loop(emit, grid=FUSED_GRID, steps=FUSED_STEPS,
                   backends=("jnp_naive", "jnp_fused")):
    """Fused on-device time loop vs host-driven loop, steps/sec both ways.

    The fused path lowers all ``steps`` iterations into one jitted program
    (single dispatch, carry-resident pre-padded fields); the host path is N
    dispatches with a fresh ``jnp.pad`` round per step — the round trip the
    paper's device-resident dataflow eliminates.
    """
    p = pw_advection()
    fields, scalars, coeffs = _data(p, grid)
    update = pw_advection_update(0.1)
    pts = float(np.prod(grid))
    tag = "x".join(str(g) for g in grid)
    for backend in backends:
        ex = compile_program(p, grid, backend=backend)
        exN = compile_program(p, grid, backend=backend, steps=steps,
                              update=update)
        modes = (
            ("host_loop", lambda: run_time_loop(ex, dict(fields), scalars,
                                                coeffs, steps, update)),
            ("fused_loop", lambda: exN(fields, scalars, coeffs)),
        )
        sps = {}
        for mode, fn in modes:
            jax.block_until_ready(fn()["u"])        # compile + warm
            dt = float("inf")
            for _ in range(3):                      # best-of-3 (CPU noise)
                t0 = time.perf_counter()
                out = fn()
                jax.block_until_ready(out["u"])
                dt = min(dt, time.perf_counter() - t0)
            sps[mode] = steps / dt
            emit(f"fig4/pw_advection/fused/{tag}/{backend}/{mode}",
                 dt * 1e6, f"{steps / dt:.2f} steps/s "
                           f"{pts * steps / dt / 1e6:.1f} MPt/s")
        emit(f"fig4/pw_advection/fused/{tag}/{backend}/speedup", 0.0,
             f"{sps['fused_loop'] / sps['host_loop']:.2f}x fused vs host")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fused-loop", action="store_true",
                    help="run only the fused-vs-host time-loop comparison")
    ap.add_argument("--steps", type=int, default=FUSED_STEPS)
    ap.add_argument("--grid", default="x".join(map(str, FUSED_GRID)),
                    help="AxBxC grid for --fused-loop")
    ap.add_argument("--backends", default="jnp_naive,jnp_fused",
                    help="comma list; add pallas for the interpret-mode "
                         "kernels (slow on CPU)")
    args = ap.parse_args()

    def emit(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}", flush=True)

    if args.fused_loop:
        grid = tuple(int(g) for g in args.grid.split("x"))
        if len(grid) != 3:
            ap.error(f"--grid must be AxBxC (3-D), got {args.grid!r}")
        run_fused_loop(emit, grid=grid, steps=args.steps,
                       backends=tuple(args.backends.split(",")))
    else:
        run(emit)


if __name__ == "__main__":
    main()
