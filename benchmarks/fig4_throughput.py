"""Paper Fig. 4: stencil throughput (MPt/s) across 'frameworks'.

Framework role mapping (DESIGN.md §7):
    jnp_naive  -> unoptimised Vitis HLS / -O0 (no reuse structure)
    jnp_fused  -> DaCe (optimising, not stencil-specialised)
    pallas     -> Stencil-HMLS (this work): generated dataflow kernels

Two number sets, clearly labelled:
  * measured — wall-clock on this CPU container (jnp backends; the pallas
    interpreter is a correctness tool, not a performance proxy)
  * modeled  — TPU v5e roofline MPt/s per backend from the streaming model
    (analysis.stencil_roofline), the apples-to-apples Fig.4 analogue
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.stencil_roofline import model_program
from repro.apps import pw_advection, tracer_advection
from repro.core import compile_program

# paper sizes: 8M / 32M points (134M is modeled only on this container)
SIZES = {
    "8M": (256, 256, 128),
    "32M": (512, 256, 256),
}
MODEL_ONLY_SIZES = {"134M": (1024, 512, 256)}


def _data(p, grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32))
              for f in p.input_fields()}
    if "e3t" in fields:
        fields["e3t"] = jnp.abs(fields["e3t"]) + 1.0
    if "msk" in fields:
        fields["msk"] = (fields["msk"] > 0).astype(jnp.float32)
    scalars = {s: jnp.float32(0.1) for s in p.scalars}
    coeffs = {c: jnp.asarray(rng.normal(size=(grid[ax],)).astype(np.float32))
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


def _time(fn, *args, iters=3):
    fn(*args)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(emit):
    for prog_fn in (pw_advection, tracer_advection):
        p = prog_fn()
        model = model_program(p)
        for size, grid in SIZES.items():
            pts = float(np.prod(grid))
            fields, scalars, coeffs = _data(p, grid)
            for backend in ("jnp_naive", "jnp_fused"):
                ex = compile_program(p, grid, backend=backend)
                dt = _time(ex, fields, scalars, coeffs)
                emit(f"fig4/{p.name}/{size}/{backend}/measured_cpu",
                     dt * 1e6, f"{pts / dt / 1e6:.1f} MPt/s")
            for backend in ("jnp_naive", "jnp_fused", "pallas"):
                mp = model.mpts(backend)
                emit(f"fig4/{p.name}/{size}/{backend}/modeled_v5e",
                     pts / (mp * 1e6) * 1e6, f"{mp:.1f} MPt/s")
        for size, grid in MODEL_ONLY_SIZES.items():
            pts = float(np.prod(grid))
            for backend in ("jnp_naive", "jnp_fused", "pallas"):
                mp = model.mpts(backend)
                emit(f"fig4/{p.name}/{size}/{backend}/modeled_v5e",
                     pts / (mp * 1e6) * 1e6, f"{mp:.1f} MPt/s")
        # the paper's headline ratio: ours vs next-best automated tool
        ratio = model.mpts("pallas") / model.mpts("jnp_fused")
        emit(f"fig4/{p.name}/speedup_vs_next_best", 0.0,
             f"{ratio:.1f}x modeled (paper: 14-100x vs DaCe)")
