"""Paper Figs. 5/6: power draw and energy per kernel execution.

No power rail is measurable in this container, so energy is MODELED per the
paper's own definition (J = average power x execution time), using the TPU
v5e busy-power envelope for the modeled execution times from the Fig.4
streaming model, per backend role.  Relative energy between backends is the
meaningful quantity (it is time-ratio driven, as in the paper where
Stencil-HMLS drew slightly MORE power but 14-92x LESS energy).
"""

from __future__ import annotations


from repro import hw
from repro.analysis.stencil_roofline import model_program, modeled_energy_j
from repro.apps import pw_advection, tracer_advection

SIZES = {"8M": 8.4e6, "32M": 33.5e6, "134M": 134e6}


def run(emit):
    for prog_fn in (pw_advection, tracer_advection):
        p = prog_fn()
        model = model_program(p)
        for size, pts in SIZES.items():
            if p.name == "tracer_advection" and size == "134M":
                continue  # paper stops at 33M for tracer advection
            for backend in ("jnp_naive", "jnp_fused", "pallas"):
                j = modeled_energy_j(pts, model.mpts(backend))
                emit(f"fig5_6/{p.name}/{size}/{backend}/modeled_energy",
                     0.0, f"{j:.3f} J @ {hw.TPU_V5E.busy_watts:.0f}W")
            base = modeled_energy_j(pts, model.mpts("jnp_fused"))
            ours = modeled_energy_j(pts, model.mpts("pallas"))
            emit(f"fig5_6/{p.name}/{size}/energy_ratio", 0.0,
                 f"{base / ours:.1f}x less energy than next best "
                 f"(paper: 14-92x)")
