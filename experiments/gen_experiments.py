"""Regenerate EXPERIMENTS.md from dry-run JSONs + hand-written sections.

    PYTHONPATH=src python experiments/gen_experiments.py

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), the
hand-maintained §Perf log (experiments/perf_log.md) and §Paper-claims
(experiments/paper_claims.md), and emits EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)

PREAMBLE = """# EXPERIMENTS

All artifacts are reproducible from this repo:

* dry-run matrix: `PYTHONPATH=src python -m repro.launch.dryrun --all --pods both`
* benchmarks:     `PYTHONPATH=src python -m benchmarks.run`
* tests:          `PYTHONPATH=src pytest tests/`
* this file:      `PYTHONPATH=src python experiments/gen_experiments.py`

## Method — roofline terms (§Roofline columns)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

* **compute_s** = MODEL_FLOPS / (chips x peak).  MODEL_FLOPS = 6·N·D dense /
  6·N_active·D MoE per trained token (+ quadratic/windowed attention term),
  2·N per inference token — the standard MFU basis, exact by construction.
* **memory_s** = analytic HBM traffic per device / HBM_bw (params passes +
  activation r/w passes + remat recompute + KV-cache traffic + logits; the
  model is in `repro.analysis.roofline.analytic_traffic` with each term
  documented there).
* **collective_s** = max(HLO wire bytes, analytic wire bytes) / link_bw.
  HLO wire bytes come from parsing every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute in `compiled.as_text()`
  (result shapes x ring factors x replica-group size), with while-body
  collectives multiplied by their trip counts.

**Scan caveat (measured on this container):** XLA `cost_analysis()` counts a
`while` (scan) body ONCE — an 8-step scanned matmul reports 1/8 the FLOPs of
its unrolled twin.  Train steps scan over layers and grad-accumulation
microbatches, so raw HLO flops/bytes columns carry a documented correction
factor; analytic columns are exact.  `useful_ratio` = MODEL_FLOPS /
(corrected HLO FLOPs x chips): <1 flags redundant compute (replication,
remat, capacity padding), >1 flags residual undercount from *inner*
sequence-chunk scans (flash KV loop, SSM chunk scan) that the correction
does not reach.

Roofline fraction (the §Perf score) = compute_s / max(compute_s, memory_s,
collective_s): the fraction of the dominant-term-limited step time doing
useful math.  `mem/dev` is `compiled.memory_analysis()` (args + temps +
outputs - aliased), the capacity proof for deliverable (e).
"""


def fmt_cell(r):
    t = r["roofline"].get("terms_primary", r["roofline"]["terms_corrected"])
    peak = max(t["compute_s"], t["memory_s"], t["collective_s"])
    frac = t["compute_s"] / peak if peak else 0.0
    mem = r["memory"]["per_device_total"] / 2**30
    fits = "yes" if mem <= 16.0 else "NO"
    ur = r["roofline"].get("useful_flops_ratio", float("nan"))
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('microbatches', 1)} | {mem:.2f} | {fits} | "
            f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | {t['dominant']} | {frac:.3f} | "
            f"{ur:.2f} |")


def main():
    recs = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        r = json.load(open(f))
        if r.get("variant", "baseline") != "baseline":
            continue
        recs.append(r)

    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"].startswith("skip")]
    failed = [r for r in recs if r["status"].startswith("FAIL")]

    lines = [PREAMBLE]
    lines.append("\n## §Dry-run — lower+compile status "
                 f"({len(ok)} ok / {len(skipped)} skipped by design / "
                 f"{len(failed)} failed)\n")
    lines.append("Every (arch x shape) cell lowered and compiled with "
                 "`jax.jit(step, in_shardings=...).lower().compile()` on the "
                 "single-pod (16,16)=256-chip and multi-pod (2,16,16)="
                 "512-chip meshes.  `mb` = auto-chosen gradient-accumulation "
                 "factor; `fits` compares per-device bytes to 16 GiB HBM.\n")
    lines.append("Skipped by design (no artifacts written): `long_500k` on "
                 "the pure full-attention archs — grok-1-314b, "
                 "nemotron-4-340b, chameleon-34b, whisper-small — per the "
                 "brief (sub-quadratic attention required); run for the "
                 "SWA/local/SSM/hybrid archs.  Whisper has a decoder, so its "
                 "decode_32k cell runs (enc-dec, not encoder-only).  "
                 "40 cells − 4 skips = 36 runnable × 2 meshes = 72 "
                 "artifacts.\n")
    if failed:
        lines.append("### FAILURES\n")
        for r in failed:
            lines.append(f"* {r['arch']} {r['shape']} {r['mesh']}: "
                         f"{r['status']}")

    lines.append("\n## §Roofline — per (arch x shape x mesh), baseline rules\n")
    lines.append("| arch | shape | mesh | mb | mem/dev GiB | fits | "
                 "compute_s | memory_s | collective_s | dominant | "
                 "roofline-frac | useful_ratio |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(ok, key=lambda r: (r["arch"], order[r["shape"]],
                                       r["mesh"])):
        lines.append(fmt_cell(r))

    lines.append("""
### Reading the table

* one sentence per cell would be noise; the patterns:
  * **train cells** are compute- or collective-dominated: TP=16 over the
    `model` axis is oversized for the <10B archs — their activations·(g-1)/g
    all-reduce traffic rivals or beats compute (the §Perf cells attack this).
  * **decode cells** are collective-dominated at baseline: GQA KV heads
    (8, 5, 4, 1) do not divide tp=16, the fallback head-dim sharding makes
    every attention contraction a sharded-reduction -> per-token all-reduces
    of (B, H, ctx) logits.  Fixed by kv-length sharding in §Perf.
  * **prefill cells** sit closest to the compute roofline (big matmuls,
    windowed attention) — mem/dev is the constraint to watch.
  * **moving a term down** (per-cell note): train -> drop TP for <10B archs
    (dp_remap) or Megatron-SP; decode -> kvseq length sharding; memory ->
    microbatching (already auto) and smaller flash chunks.
* nemotron-4-340b train does NOT fit 256 chips (params+opt f32 = 4.1 TB vs
  4 TB pod HBM): the multi-pod column is the minimum viable footprint; this
  is a capacity conclusion, not a bug.
""")

    perf = os.path.join(HERE, "perf_log.md")
    if os.path.exists(perf):
        lines.append(open(perf).read())
    claims = os.path.join(HERE, "paper_claims.md")
    if os.path.exists(claims):
        lines.append(open(claims).read())

    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out}: {len(ok)} ok, {len(skipped)} skipped, "
          f"{len(failed)} failed")


if __name__ == "__main__":
    main()
