"""Stream schedule walkthrough: the paper's shift-register dataflow layer.

    PYTHONPATH=src python examples/stream_schedule.py [--kernel tracer]

Shows the HLS-dialect analogue end to end:

1. lower the stencil IR to the dataflow layer and print the stream graph —
   ``Load -> Window(depth) -> Compute[ring] -> Store`` regions, with
   window-buffer depths computed from the access offsets and fusion
   legalised (positive stream offsets split regions);
2. compile both schedules of the same program and check steps=N fused-loop
   parity between them;
3. time the fused loop under each schedule (on CPU the Pallas interpreter
   dominates; on real hardware the stream schedule is the one that fetches
   each input element once per sweep).
"""

import argparse
import time

import jax
import numpy as np

from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import CompileOptions, compile_program, lower_to_dataflow
from repro.core.schedule import auto_plan
from repro.analysis.stencil_roofline import plan_bytes_per_point

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--kernel", default="pw", choices=("pw", "tracer"))
ap.add_argument("--steps", type=int, default=10)
ap.add_argument("--boundary", default="zero", choices=("zero", "periodic"))
ap.add_argument("--time-tile", type=int, default=4,
                help="temporal-blocking depth for the chained stream run")
ap.add_argument("--plane-tile", type=int, default=4,
                help="spatial-unrolling width for the plane-tiled stream "
                     "run (P planes per sweep grid step)")
args = ap.parse_args()

if args.kernel == "pw":
    p = pw_advection(boundary=args.boundary)
    update = pw_advection_update(0.1)
    grid = (32, 32, 128)
else:
    p = tracer_advection(boundary=args.boundary)
    update = tracer_advection_update()
    grid = (16, 16, 64)

rng = np.random.default_rng(0)
fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
          for f in p.input_fields()}
if "e3t" in fields:
    fields["e3t"] = np.abs(fields["e3t"]) + 1.0
scalars = {s: np.float32(0.05) for s in p.scalars}
coeffs = {c: np.linspace(0.9, 1.1, grid[ax]).astype(np.float32)
          for c, ax in p.coeffs.items()}

# -- 1. the dataflow layer: stencil IR -> stream graph ----------------------
plan = auto_plan(p, grid, schedule="stream")
graph = lower_to_dataflow(p, plan)
print(graph.to_text())
print()
for r in graph.regions:
    print(f"  {r.describe()}")
print(f"  modeled bytes/point: stream="
      f"{plan_bytes_per_point(p, plan, grid):.1f} vs "
      f"block={plan_bytes_per_point(p, auto_plan(p, grid), grid):.1f}")
print()

# -- 2. both schedules, one fused loop each, parity -------------------------
# CompileOptions is the canonical configuration object; loose kwargs
# normalise to the same thing.  time_tile chains T time steps through one
# stream sweep (legalisation may demote it — see the printed effective
# depth); on the block schedule it does not apply.
execs = {}
for label, opts in (
    ("block", CompileOptions(schedule="block", steps=args.steps,
                             update=update)),
    ("stream", CompileOptions(schedule="stream", steps=args.steps,
                              update=update)),
    (f"stream/T={args.time_tile}",
     CompileOptions(schedule="stream", steps=args.steps, update=update,
                    time_tile=args.time_tile)),
    (f"stream/P={args.plane_tile}",
     CompileOptions(schedule="stream", steps=args.steps, update=update,
                    plane_tile=args.plane_tile)),
    (f"stream/P={args.plane_tile}/T={args.time_tile}",
     CompileOptions(schedule="stream", steps=args.steps, update=update,
                    time_tile=args.time_tile,
                    plane_tile=args.plane_tile)),
):
    execs[label] = compile_program(p, grid, options=opts)
tiled = execs[f"stream/T={args.time_tile}"]
print(f"requested time_tile={args.time_tile}, effective "
      f"{tiled.plan.stream.time_tile} (legalisation demotes chains that "
      f"cross region splits or periodic wraps)")
unrolled = execs[f"stream/P={args.plane_tile}"]
print(f"requested plane_tile={args.plane_tile}, effective "
      f"{unrolled.plan.stream.plane_tile} (legalisation demotes sweeps "
      f"wider than the stream extent)")
out = {s: ex(fields, scalars, coeffs) for s, ex in execs.items()}
worst = max(float(np.abs(np.asarray(out[s][k])
                         - np.asarray(out["block"][k])).max())
            for s in out if s != "block" for k in out["block"])
print(f"fused steps={args.steps} parity vs block schedule: "
      f"max|diff| = {worst:.2e}")
assert worst < 1e-5

# -- 3. fused-loop timing under each schedule -------------------------------
for schedule, ex in execs.items():
    jax.block_until_ready(ex(fields, scalars, coeffs)[next(iter(fields))])
    dt = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        res = ex(fields, scalars, coeffs)
        jax.block_until_ready(res[next(iter(fields))])
        dt = min(dt, time.perf_counter() - t0)
    print(f"{schedule:>12}: {args.steps / dt:8.2f} steps/s "
          f"({dt * 1e6:.0f} us for {args.steps} fused steps)")
