"""PW advection solver (paper benchmark 1): a real time-stepping run.

    PYTHONPATH=src python examples/pw_advection.py --size 8M --steps 5
    PYTHONPATH=src python examples/pw_advection.py --fused-loop --steps 20

Integrates the MONC Piacsek-Williams advection source terms over several
steps (forward Euler on the wind fields), using the generated Pallas
dataflow kernels, and reports MPt/s per application.  ``--fused-loop``
compiles the whole time loop into one on-device program (the paper's
device-resident inter-iteration dataflow) and reports steps/sec for both
execution modes.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import pw_advection, pw_advection_update
from repro.core import compile_program, run_time_loop

SIZES = {"1M": (128, 64, 128), "8M": (256, 256, 128), "32M": (512, 256, 256)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="1M", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "jnp_fused", "jnp_naive"])
    ap.add_argument("--fused-loop", action="store_true",
                    help="compile the whole time loop on device and compare "
                         "steps/sec against the host-driven loop")
    args = ap.parse_args()

    grid = SIZES[args.size]
    p = pw_advection()
    ex = compile_program(p, grid, backend=args.backend)
    print("plan:", ex.plan.describe())

    rng = np.random.default_rng(0)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.1)
              for f in ("u", "v", "w")}
    scalars = {"tcx": jnp.float32(0.05), "tcy": jnp.float32(0.05)}
    coeffs = {c: jnp.asarray(np.linspace(0.9, 1.1, grid[2]).astype(np.float32))
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    dt = 0.1
    pts = float(np.prod(grid))
    update = pw_advection_update(dt)

    if args.fused_loop:
        exN = compile_program(p, grid, backend=args.backend,
                              steps=args.steps, update=update)
        print("time loop:", exN.time_spec.describe())
        for label, fn in (
                ("host loop ", lambda: run_time_loop(
                    ex, dict(fields), scalars, coeffs, args.steps, update)),
                ("fused loop", lambda: exN(fields, scalars, coeffs))):
            jax.block_until_ready(fn()["u"])    # warm-up (compile)
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out["u"])
            el = time.perf_counter() - t0
            print(f"{label}: {args.steps} steps in {el*1e3:8.1f} ms  "
                  f"{args.steps/el:8.2f} steps/s  "
                  f"{pts*args.steps/el/1e6:8.2f} MPt/s")
            assert bool(jnp.isfinite(out["u"]).all())
        print("pw_advection fused-loop OK")
        return

    for step in range(args.steps):
        t0 = time.perf_counter()
        src = ex(fields, scalars, coeffs)
        fields = update(fields, src)
        jax.block_until_ready(fields["u"])
        el = time.perf_counter() - t0
        umax = float(jnp.abs(fields["u"]).max())
        print(f"step {step}: {el*1e3:8.1f} ms  {pts/el/1e6:8.2f} MPt/s  "
              f"|u|max={umax:.4f}")
    assert np.isfinite(umax)
    print("pw_advection OK")


if __name__ == "__main__":
    main()
