"""PW advection solver (paper benchmark 1): a real time-stepping run.

    PYTHONPATH=src python examples/pw_advection.py --size 8M --steps 5

Integrates the MONC Piacsek-Williams advection source terms over several
steps (forward Euler on the wind fields), using the generated Pallas
dataflow kernels, and reports MPt/s per application.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import pw_advection
from repro.core import compile_program

SIZES = {"1M": (128, 64, 128), "8M": (256, 256, 128), "32M": (512, 256, 256)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="1M", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "jnp_fused", "jnp_naive"])
    args = ap.parse_args()

    grid = SIZES[args.size]
    p = pw_advection()
    ex = compile_program(p, grid, backend=args.backend)
    print("plan:", ex.plan.describe())

    rng = np.random.default_rng(0)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.1)
              for f in ("u", "v", "w")}
    scalars = {"tcx": jnp.float32(0.05), "tcy": jnp.float32(0.05)}
    coeffs = {c: jnp.asarray(np.linspace(0.9, 1.1, grid[2]).astype(np.float32))
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    dt = 0.1
    pts = float(np.prod(grid))

    for step in range(args.steps):
        t0 = time.perf_counter()
        src = ex(fields, scalars, coeffs)
        fields = {
            "u": fields["u"] + dt * src["su"],
            "v": fields["v"] + dt * src["sv"],
            "w": fields["w"] + dt * src["sw"],
        }
        jax.block_until_ready(fields["u"])
        el = time.perf_counter() - t0
        umax = float(jnp.abs(fields["u"]).max())
        print(f"step {step}: {el*1e3:8.1f} ms  {pts/el/1e6:8.2f} MPt/s  "
              f"|u|max={umax:.4f}")
    assert np.isfinite(umax)
    print("pw_advection OK")


if __name__ == "__main__":
    main()
