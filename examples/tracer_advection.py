"""NEMO tracer advection (paper benchmark 2): 24 stencil ops / 6 fields.

    PYTHONPATH=src python examples/tracer_advection.py --size 8M --steps 3
    PYTHONPATH=src python examples/tracer_advection.py --fused-loop

Demonstrates the dependency-chain handling (producer->consumer temps inside
one fused dataflow kernel with overlapped-tiling recompute) on the paper's
harder benchmark, and compares the three stage-split strategies.
``--fused-loop`` additionally compiles the whole tracer time loop into one
on-device program and reports steps/sec for both execution modes.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import tracer_advection, tracer_advection_update
from repro.core import compile_program, run_time_loop

SIZES = {"1M": (128, 64, 128), "8M": (256, 256, 128), "33M": (512, 256, 256)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="1M", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--fused-loop", action="store_true",
                    help="compile the whole time loop on device and compare "
                         "steps/sec against the host-driven loop")
    args = ap.parse_args()

    grid = SIZES[args.size]
    p = tracer_advection()
    rng = np.random.default_rng(1)
    fields = {
        "t": jnp.asarray(rng.normal(size=grid).astype(np.float32) + 15.0),
        "un": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "vn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "wn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.05),
        "e3t": jnp.asarray(np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0),
        "msk": jnp.asarray((rng.uniform(size=grid) > 0.05).astype(np.float32)),
    }
    scalars = {"rdt": jnp.float32(0.05), "zeps": jnp.float32(1e-6)}
    coeffs = {"ztfreez": jnp.asarray(np.full(grid[2], -1.8, np.float32))}
    pts = float(np.prod(grid))

    if not args.fused_loop:
        for strategy in ("fused", "per_field", "auto"):
            ex = compile_program(p, grid, backend="jnp_fused"
                                 if strategy == "auto" else "pallas",
                                 strategy=strategy)
            t0 = time.perf_counter()
            out = ex(fields, scalars, coeffs)
            jax.block_until_ready(out["ta"])
            el = time.perf_counter() - t0
            print(f"strategy={strategy:9s} groups="
                  f"{len(ex.plan.groups):2d} first-call {el:6.2f}s")

    if args.fused_loop:
        update = tracer_advection_update()
        ex = compile_program(p, grid, backend="jnp_fused")
        exN = compile_program(p, grid, backend="jnp_fused",
                              steps=args.steps, update=update)
        print("time loop:", exN.time_spec.describe())
        for label, fn in (
                ("host loop ", lambda: run_time_loop(
                    ex, dict(fields), scalars, coeffs, args.steps, update)),
                ("fused loop", lambda: exN(fields, scalars, coeffs))):
            jax.block_until_ready(fn()["t"])    # warm-up (compile)
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out["t"])
            el = time.perf_counter() - t0
            print(f"{label}: {args.steps} steps in {el*1e3:8.1f} ms  "
                  f"{args.steps/el:8.2f} steps/s  "
                  f"{pts*args.steps/el/1e6:8.2f} MPt/s")
            assert bool(jnp.isfinite(out["t"]).all())
        print("tracer_advection fused-loop OK")
        return

    ex = compile_program(p, grid, backend="jnp_fused")
    tr = fields["t"]
    for step in range(args.steps):
        t0 = time.perf_counter()
        out = ex(dict(fields, t=tr), scalars, coeffs)
        tr = out["ta"]
        jax.block_until_ready(tr)
        el = time.perf_counter() - t0
        print(f"step {step}: {el*1e3:8.1f} ms  {pts/el/1e6:8.2f} MPt/s  "
              f"t-mean={float(tr.mean()):.4f}")
    assert bool(jnp.isfinite(tr).all())
    print("tracer_advection OK")


if __name__ == "__main__":
    main()
