"""Quickstart: write a stencil, let the compiler structure it for TPU.

    PYTHONPATH=src python examples/quickstart.py

Builds a 3-D 7-point diffusion stencil through the frontend (the PSyclone
role), lowers it through all three backends, checks they agree, and prints
the plan the auto-scheduler derived (the HLS-dialect analogue).
"""

import numpy as np

from repro.core import ProgramBuilder, compile_program
from repro.core.schedule import auto_plan, vmem_cost

# -- 1. write the maths (stencil dialect analogue) --------------------------
b = ProgramBuilder("diffusion", ndim=3)
u = b.input("u")
alpha = b.scalar("alpha")
out = b.output("u_next")
b.define(out, u[0, 0, 0] + alpha * (
    u[1, 0, 0] + u[-1, 0, 0] + u[0, 1, 0] + u[0, -1, 0]
    + u[0, 0, 1] + u[0, 0, -1] - 6.0 * u[0, 0, 0]))
prog = b.build()
print(prog.to_text())

# -- 2. auto-plan (HLS dialect analogue) ------------------------------------
grid = (64, 64, 256)
plan = auto_plan(prog, grid)
print("\nplan:", plan.describe())
print(f"VMEM claim: {vmem_cost(prog, plan, grid)/2**20:.2f} MiB")

# -- 3. run all three backends ----------------------------------------------
rng = np.random.default_rng(0)
fields = {"u": rng.normal(size=grid).astype(np.float32)}
scalars = {"alpha": np.float32(0.1)}

results = {}
for backend in ("jnp_naive", "jnp_fused", "pallas"):
    ex = compile_program(prog, grid, backend=backend)
    results[backend] = np.asarray(ex(fields, scalars)["u_next"])

for k in ("jnp_fused", "pallas"):
    ok = np.allclose(results["jnp_naive"], results[k], atol=1e-5)
    print(f"{k} matches oracle: {ok}")
print("quickstart OK")
