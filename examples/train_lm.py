"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Full production loop: synthetic (restartable) data pipeline, AdamW with
cosine schedule, grad clipping, async atomic checkpoints every 50 steps,
auto-resume — kill it mid-run and re-launch to see recovery.
"""

import argparse

from repro.configs.base import ModelConfig
from repro.data import BatchSpec, SyntheticLM
from repro.train import OptConfig, TrainConfig, Trainer


def preset(name: str):
    if name == "tiny":        # CI-speed sanity run
        cfg = ModelConfig(name="tiny-lm", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=2, d_ff=256, vocab=2048,
                          window=64, layer_pattern=("local",))
        spec = BatchSpec(global_batch=8, seq_len=64, vocab=cfg.vocab)
        return cfg, spec
    if name == "100m":        # ~100M params (danube-family reduction)
        cfg = ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
                          window=1024, layer_pattern=("local",))
        spec = BatchSpec(global_batch=4, seq_len=256, vocab=cfg.vocab)
        return cfg, spec
    raise KeyError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg, spec = preset(args.preset)
    print(f"model {cfg.name}: {cfg.num_params()/1e6:.1f}M params")
    tcfg = TrainConfig(
        opt=OptConfig(lr=3e-4, warmup_steps=20, total_steps=max(args.steps,
                                                                100)),
        ckpt_every=50, ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(cfg, tcfg, SyntheticLM(spec, seed=0))
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")
    hist = trainer.run(args.steps)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")
    print("train_lm OK")


if __name__ == "__main__":
    main()
