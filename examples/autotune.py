"""Auto-tuning walkthrough: let the tooling pick the dataflow plan.

    PYTHONPATH=src python examples/autotune.py [--backend pallas]

The paper's point is that the transformation space is searched by the
*compiler*, not the programmer.  This example closes that loop end to end:

1. first ``compile_program(..., strategy="tuned")`` call — cache miss: the
   tuner prunes candidates with the VMEM + roofline models, measures the
   survivors on-device (single-step and fused ``steps=N``), and persists
   the winner in a JSON plan cache;
2. second call — pure cache hit: the stored plan compiles immediately,
   zero timed runs;
3. the tuned executable is checked against the ``auto_plan`` heuristic for
   both numerics and steps/sec.
"""

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.apps import pw_advection, pw_advection_update
from repro.core import CompileOptions, PlanCache, TuneConfig, compile_program

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--backend", default="jnp_fused",
                choices=("jnp_naive", "jnp_fused", "pallas"))
ap.add_argument("--steps", type=int, default=5)
args = ap.parse_args()

p = pw_advection()
grid = (32, 32, 128)
update = pw_advection_update(0.1)
rng = np.random.default_rng(0)
fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
          for f in p.input_fields()}
scalars = {s: np.float32(0.05) for s in p.scalars}
coeffs = {c: np.linspace(0.9, 1.1, grid[ax]).astype(np.float32)
          for c, ax in p.coeffs.items()}

_tmpdir = tempfile.TemporaryDirectory(prefix="stencil_hmls_")
cache = PlanCache(path=f"{_tmpdir.name}/plan_cache.json")
cfg = TuneConfig(steps=args.steps, repeats=2, max_measured=4)

# one frozen CompileOptions is shared verbatim between both tuned compiles
# (the canonical API; loose kwargs still work and normalise to the same)
opts = CompileOptions(backend=args.backend, strategy="tuned",
                      steps=args.steps, update=update,
                      tune_config=cfg, plan_cache=cache)

# -- 1. cache miss: the tuner searches the plan space by measurement --------
t0 = time.perf_counter()
ex_tuned = compile_program(p, grid, options=opts)
print(f"tuned (cache miss, measured search): {time.perf_counter()-t0:.2f}s")
print("  winning plan:", ex_tuned.plan.describe())

# -- 2. cache hit: zero timed runs ------------------------------------------
t0 = time.perf_counter()
compile_program(p, grid, options=opts)
print(f"tuned (cache hit): {time.perf_counter()-t0:.2f}s  -> {cache.path}")

# -- 3. tuned vs heuristic: same numbers, at least the same speed -----------
ex_auto = compile_program(p, grid, backend=args.backend,
                          steps=args.steps, update=update)
out_t = ex_tuned(fields, scalars, coeffs)
out_a = ex_auto(fields, scalars, coeffs)
for k in out_a:
    np.testing.assert_allclose(np.asarray(out_t[k]), np.asarray(out_a[k]),
                               atol=1e-5, rtol=1e-5)
print("tuned matches auto_plan numerics")

for name, ex in (("auto_plan", ex_auto), ("tuned", ex_tuned)):
    jax.block_until_ready(ex(fields, scalars, coeffs)["u"])   # warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(ex(fields, scalars, coeffs)["u"])
        best = min(best, time.perf_counter() - t0)
    print(f"  {name:10s} {args.steps / best:8.2f} steps/s")
print("autotune OK")
