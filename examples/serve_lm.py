"""Batched serving example: prefill + ring-buffer decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b --tokens 24

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the sliding-window layers keep bounded ring-buffer KV caches (the
sequence shift buffer) while global layers keep full caches.
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke
from repro.models import init_lm
from repro.models import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b",
                    choices=[a for a in ARCHS if a != "whisper_small"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch=args.batch, max_len=256,
                         temperature=args.temperature)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, 12)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=args.tokens, seed=1)
    for i, row in enumerate(out):
        print(f"seq {i}: {row.tolist()}")
    print(f"decoded {engine.stats.decode_tokens} tokens "
          f"(prefill {engine.stats.prefill_tokens})")
    print("serve_lm OK")


if __name__ == "__main__":
    main()
