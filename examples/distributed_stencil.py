"""Distributed stencil run: domain decomposition + halo exchange on a
simulated 8-device mesh.

    PYTHONPATH=src python examples/distributed_stencil.py

(Sets the XLA host-device override itself; run as a standalone script.)
"""

import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.apps import pw_advection                     # noqa: E402
from repro.core import compile_program                  # noqa: E402
from repro.core.distribute import make_sharded_executor  # noqa: E402
from repro.dist.sharding import make_auto_mesh          # noqa: E402


def main():
    mesh = make_auto_mesh((2, 2, 2), ("X", "Y", "Z"))
    p = pw_advection()
    grid = (64, 64, 128)
    rng = np.random.default_rng(0)
    fields = {f: rng.normal(size=grid).astype(np.float32)
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}

    dist = make_sharded_executor(p, grid, mesh, ("X", "Y", "Z"))
    print(f"local block per device: {dist.local_grid}, "
          f"plan {dist.plan.describe()}")
    out = dist(fields, scalars, coeffs)

    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars,
                                                        coeffs)
    for k in ref:
        err = float(np.abs(np.asarray(out[k]) - np.asarray(ref[k])).max())
        print(f"{k}: sharded-vs-single max err = {err:.2e}")
        assert err < 1e-4
    print("distributed_stencil OK")


if __name__ == "__main__":
    main()
