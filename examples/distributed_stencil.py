"""Distributed stencil run through the unified compile pipeline.

``compile_program`` is the single entry point for local AND SPMD
execution: pass ``mesh=``/``mesh_axes=`` to domain-decompose the grid over
a device mesh, add ``steps=N`` to fuse the whole time loop into one
dispatch with the halo exchange *inside* the loop carry (ppermute-refresh-
then-compute, no host round trips), and ``boundary="periodic"`` to run the
same program on a torus.

    PYTHONPATH=src python examples/distributed_stencil.py

(Sets the XLA host-device override itself; run as a standalone script.
The old ``make_sharded_executor`` entry point is deprecated — it now
forwards here.)
"""

import os
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.apps import pw_advection, pw_advection_update  # noqa: E402
from repro.core import compile_program, run_time_loop   # noqa: E402
from repro.dist.sharding import make_auto_mesh          # noqa: E402


def main():
    mesh = make_auto_mesh((2, 2, 2), ("X", "Y", "Z"))
    grid = (64, 64, 128)
    steps = 8
    rng = np.random.default_rng(0)
    # modest amplitudes: the PW scheme is quadratic, and forward Euler on
    # O(1) winds amplifies rounding noise across steps
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    update = pw_advection_update(0.05)

    # --- one sharded step: same API as a local compile, plus mesh= -------
    p = pw_advection()
    dist = compile_program(p, grid, backend="pallas", mesh=mesh,
                           mesh_axes=("X", "Y", "Z"))
    print(f"{dist.shard.describe()}\nplan {dist.plan.describe()}")
    out = dist(fields, scalars, coeffs)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars,
                                                        coeffs)
    for k in ref:
        err = float(np.abs(np.asarray(out[k]) - np.asarray(ref[k])).max())
        print(f"single-step {k}: sharded-vs-local max err = {err:.2e}")
        assert err < 1e-4

    # --- the fused distributed time loop: N steps, ONE dispatch ----------
    for boundary in ("zero", "periodic"):
        pb = pw_advection(boundary=boundary)
        exN = compile_program(pb, grid, backend="jnp_fused", mesh=mesh,
                              mesh_axes=("X", "Y", "Z"), steps=steps,
                              update=update)
        jax.block_until_ready(exN(fields, scalars, coeffs)["u"])  # warm
        t0 = time.perf_counter()
        got = exN(fields, scalars, coeffs)
        jax.block_until_ready(got["u"])
        dt = time.perf_counter() - t0
        want = run_time_loop(compile_program(pb, grid, backend="jnp_fused"),
                             dict(fields), scalars, coeffs, steps, update)
        err = max(float(np.abs(np.asarray(got[k])
                               - np.asarray(want[k])).max()) for k in want)
        print(f"fused loop ({boundary}): {steps} distributed steps in one "
              f"dispatch, {steps / dt:.1f} steps/s, max err = {err:.2e}")
        assert err < 1e-4
    print("distributed_stencil OK")


if __name__ == "__main__":
    main()
