"""End-to-end observability: trace a compile -> tune -> serve run.

    PYTHONPATH=src python examples/trace_compile.py [--out TRACE.json]

One :class:`repro.obs.Tracer` watches the whole stack:

1. a **tuned compile** — the tuner's candidate loop shows up as nested
   ``tune.candidate`` spans under the ``compile`` span, each carrying its
   measured time and roofline-achieved fraction, and the winning plan is
   announced as a ``PlanChosen`` event;
2. a **serving session** — the engine pins the same tracer, so executor
   builds, cache hits/misses, and every ``serve.batch`` land in the same
   timeline;
3. the trace exports to Chrome ``trace_event`` JSON — open it in
   ``chrome://tracing`` or https://ui.perfetto.dev — plus optional JSONL
   for machine grep.  Process-wide metrics print at the end.

The same trace can be captured with zero code changes by running any
entry point under ``REPRO_TRACE=path``.
"""

import argparse
import json

import numpy as np

from repro.apps import pw_advection, pw_advection_update
from repro.core import PlanCache, TuneConfig, compile_program
from repro.obs import Tracer, global_metrics
from repro.serve import StencilEngine, StencilRequest

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--out", default="TRACE_compile.json",
                help="Chrome trace_event JSON output path")
ap.add_argument("--jsonl", default=None,
                help="also export raw records as JSONL")
args = ap.parse_args()

p = pw_advection()
grid = (16, 16, 16)
update = pw_advection_update(0.1)
rng = np.random.default_rng(0)
fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
          for f in p.input_fields()}
scalars = {s: np.float32(0.05) for s in p.scalars}
coeffs = {c: np.linspace(0.9, 1.1, grid[ax]).astype(np.float32)
          for c, ax in p.coeffs.items()}

tracer = Tracer()

# -- 1. traced tuned compile ------------------------------------------------
ex = compile_program(
    p, grid, backend="pallas", strategy="tuned", steps=3, update=update,
    tune_config=TuneConfig(steps=3, repeats=1, max_measured=3),
    plan_cache=PlanCache(path=None), trace=tracer)
chosen = tracer.events("PlanChosen")[-1]["args"]
print(f"plan chosen: {chosen['label']} (schedule={chosen['schedule']}, "
      f"roofline_fraction={chosen['roofline_fraction']:.3e})")

# -- 2. traced serving ------------------------------------------------------
with StencilEngine(backend="jnp_fused", max_batch=4, window_s=0.005,
                   tracer=tracer) as eng:
    futs = [eng.submit(StencilRequest(program=p, fields=fields,
                                      scalars=scalars, coeffs=coeffs))
            for _ in range(4)]
    for f in futs:
        f.result(600)
print(f"served {eng.stats.completed} requests in "
      f"{eng.stats.batches} batches")

# -- 3. export --------------------------------------------------------------
n = tracer.export_chrome(args.out)
print(f"wrote {args.out}: {n} trace events "
      f"({len(tracer.spans())} spans, {len(tracer.events())} events)")
if args.jsonl:
    tracer.export_jsonl(args.jsonl)
    print(f"wrote {args.jsonl}")

summary = {
    "spans": sorted({s["name"] for s in tracer.spans()}),
    "events": sorted({e["name"] for e in tracer.events()}),
    "tune_candidates": len(tracer.spans("tune.candidate")),
    "serve_batches": len(tracer.spans("serve.batch")),
    "metrics": global_metrics().snapshot(),
}
print(json.dumps(summary, indent=2, default=str))

assert tracer.spans("compile"), "no compile span recorded"
assert summary["tune_candidates"] >= 2, "expected >= 2 tuner candidates"
assert summary["serve_batches"] >= 1, "expected >= 1 serve batch"
rf = chosen["roofline_fraction"]
assert rf is not None and 0 < rf < float("inf"), rf
