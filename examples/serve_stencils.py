"""Stencil serving example: mixed-shape traffic through the async engine.

    PYTHONPATH=src python examples/serve_stencils.py --requests 16

A stream of PW-advection requests with four different grid shapes goes
through one StencilEngine.  The engine rounds each grid up to a
lane-quantised bucket, compiles one executor per bucket (grids that share
a bucket share a trace — sizes are traced scalars), micro-batches
same-bucket requests under ``vmap``, and answers on futures.  Every answer
is checked against a direct ``compile_program`` at the request's true
grid.
"""

import argparse

import numpy as np

from repro.apps import pw_advection, pw_advection_update
from repro.core import compile_program
from repro.serve import StencilEngine, StencilRequest

GRIDS = [(16, 16, 16), (12, 14, 16), (16, 16, 24), (10, 16, 16)]


def make_request(p, update, grid, rng, steps):
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": 0.05, "tcy": 0.05}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return StencilRequest(program=p, fields=fields, scalars=scalars,
                          coeffs=coeffs, steps=steps, update=update,
                          update_key="pw/dt=0.1")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--backend", default="jnp_fused",
                    choices=["jnp_fused", "jnp_naive", "pallas"])
    ap.add_argument("--boundary", default="zero",
                    choices=["zero", "periodic"])
    args = ap.parse_args()

    p = pw_advection(boundary=args.boundary)
    update = pw_advection_update(0.1)
    rng = np.random.default_rng(0)
    reqs = [make_request(p, update, GRIDS[i % len(GRIDS)], rng, args.steps)
            for i in range(args.requests)]

    with StencilEngine(backend=args.backend, max_batch=4,
                       window_s=0.005) as eng:
        results = eng.map(reqs, timeout=600)
        for req, res in zip(reqs, results):
            grid = req.grid()
            ref = compile_program(p, grid, backend=args.backend,
                                  steps=args.steps, update=update)(
                req.fields, req.scalars, req.coeffs)
            err = max(np.abs(np.asarray(ref[k]) - res.outputs[k]).max()
                      for k in ref)
            print(f"grid {grid} -> bucket {res.bucket.bucket} "
                  f"batch={res.batch_size} lat={res.latency_ms:.1f}ms "
                  f"maxerr={err:.2e}")
            assert err < 1e-5
        s = eng.stats
        print(f"{s.completed} requests, {s.compiles} compiles, "
              f"hit_rate={s.cache_hit_rate():.2f} "
              f"occupancy={s.occupancy():.2f} "
              f"throughput={s.throughput():.1f} req/s "
              f"p50={s.p50_ms():.1f}ms p99={s.p99_ms():.1f}ms")
    print("serve_stencils OK")


if __name__ == "__main__":
    main()
