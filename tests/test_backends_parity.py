"""Backend parity: Pallas (interpret) vs jnp oracles, incl. property fuzzing.

Every Pallas kernel configuration is checked against the pure-jnp oracle
(``jnp_naive``) — the repo-wide invariant that the generated dataflow code
computes exactly the mathematics of the IR.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # property fuzzing needs the test extra; plain parity tests don't
    from hypothesis import given, settings, HealthCheck
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.apps import pw_advection, tracer_advection
from repro.core import compile_program
from repro.core.schedule import DataflowPlan, auto_plan
from repro.core.passes import stage_split

from strategies import make_data

if HAVE_HYPOTHESIS:
    from strategies import programs


def physical_data(p, grid, seed=0):
    fields, scalars, coeffs = make_data(p, grid, seed)
    if "e3t" in fields:
        fields["e3t"] = np.abs(fields["e3t"]) + 1.0
    if "msk" in fields:
        fields["msk"] = (fields["msk"] > 0).astype(np.float32)
    if "zeps" in scalars:
        scalars["zeps"] = np.float32(1e-6)
    return fields, scalars, coeffs


def check_parity(p, grid, strategy="auto", atol=1e-4, rtol=1e-4, seed=0):
    fields, scalars, coeffs = physical_data(p, grid, seed)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars, coeffs)
    for backend in ["jnp_fused", "pallas"]:
        got = compile_program(p, grid, backend=backend,
                              strategy=strategy)(fields, scalars, coeffs)
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(ref[k]), atol=atol, rtol=rtol,
                err_msg=f"{p.name}/{k} backend={backend} grid={grid}")


# ---------------------------------------------------------------- paper apps

@pytest.mark.parametrize("grid", [(8, 8, 32), (12, 10, 130), (16, 16, 256)])
def test_pw_advection_parity(grid):
    check_parity(pw_advection(), grid)


@pytest.mark.parametrize("strategy", ["fused", "per_field", "auto"])
def test_pw_advection_strategies(strategy):
    check_parity(pw_advection(), (10, 12, 128), strategy=strategy)


@pytest.mark.parametrize("grid", [(8, 8, 64), (12, 16, 130)])
def test_tracer_advection_parity(grid):
    check_parity(tracer_advection(), grid)


@pytest.mark.parametrize("strategy", ["fused", "per_field", "auto"])
def test_tracer_advection_strategies(strategy):
    check_parity(tracer_advection(), (8, 10, 64), strategy=strategy)


# ------------------------------------------------------- shape / dtype sweep

@pytest.mark.parametrize("grid", [(32,), (65,), (8, 48), (9, 130),
                                  (4, 6, 64), (5, 7, 96)])
def test_shape_sweep_odd_grids(grid):
    """Non-divisible grids exercise tile-alignment padding + crop."""
    from repro.core.frontend import ProgramBuilder
    b = ProgramBuilder("sweep", ndim=len(grid))
    x = b.input("x")
    o = b.output("o")
    z = (0,) * len(grid)
    off1 = tuple(1 if i == 0 else 0 for i in range(len(grid)))
    off2 = tuple(-1 if i == len(grid) - 1 else 0 for i in range(len(grid)))
    b.define(o, x[z] * 2.0 + x[off1] - x[off2])
    check_parity(b.build(), grid)


def test_bfloat16_dtype():
    p = pw_advection()
    grid = (8, 8, 128)
    fields, scalars, coeffs = physical_data(p, grid)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars, coeffs)
    got = compile_program(p, grid, backend="pallas",
                          dtype="bfloat16")(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k], dtype=np.float32),
                                   np.asarray(ref[k]), atol=0.15, rtol=0.15)


# ------------------------------------------------------------ property tests

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(p=programs())
    def test_property_random_programs_pallas_matches_oracle(p):
        grid = {1: (24,), 2: (10, 32), 3: (6, 8, 32)}[p.ndim]
        check_parity(p, grid, atol=1e-3, rtol=1e-3)

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(p=programs(ndim=3))
    def test_property_per_field_equals_fused(p):
        """Paper step 4: the per-field dataflow split must not change
        results."""
        grid = (6, 8, 32)
        fields, scalars, coeffs = make_data(p, grid, seed=3)
        a = compile_program(p, grid, backend="pallas",
                            strategy="fused")(fields, scalars, coeffs)
        b = compile_program(p, grid, backend="pallas",
                            strategy="per_field")(fields, scalars, coeffs)
        for k in a:
            np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                       atol=1e-3, rtol=1e-3)
