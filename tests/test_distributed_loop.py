"""Distributed fused time loop: ``compile_program(..., mesh=, steps=N)``.

Runs in a subprocess so the 8-device XLA host-platform override never leaks
into other tests (which must see 1 device).  Asserts the PR-4 acceptance
criteria:

* N distributed steps (pw_advection and tracer_advection, steps=4, zero
  AND periodic boundaries) match the host-side ``run_time_loop`` reference
  to 1e-5, with halo exchange inside the loop carry;
* the whole loop is ONE compiled dispatch: the update rule traces exactly
  once regardless of N and repeated calls hit the jit cache;
* a degenerate 1x1 mesh bit-matches the single-device fused loop;
* ``strategy="tuned"`` works under a mesh, with a cache key separating
  mesh topologies (zero timed runs on the second compile);
* the jnp backends are first-class sharded citizens (temp accesses route
  through ppermute shifts, coefficients slice at the shard origin).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import compile_program, run_time_loop, PlanCache, TuneConfig
from repro.dist.sharding import make_auto_mesh

rng = np.random.default_rng(7)
assert jax.device_count() == 8

def pw_data(grid):
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs

def tracer_data(grid):
    fields = {
        "t": rng.normal(size=grid).astype(np.float32) + 15.0,
        "un": rng.normal(size=grid).astype(np.float32) * 0.2,
        "vn": rng.normal(size=grid).astype(np.float32) * 0.2,
        "wn": rng.normal(size=grid).astype(np.float32) * 0.05,
        "e3t": np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0,
        "msk": (rng.uniform(size=grid) > 0.05).astype(np.float32)}
    scalars = {"rdt": np.float32(0.05), "zeps": np.float32(1e-6)}
    coeffs = {"ztfreez": np.full(grid[2], -1.8, np.float32)}
    return fields, scalars, coeffs

MESH = make_auto_mesh((2, 2, 2), ("X", "Y", "Z"))
AXES = ("X", "Y", "Z")

def check_loop(prog_fn, update, grid, data, backends, steps=4):
    for bnd in ("zero", "periodic"):
        p = prog_fn(boundary=bnd)
        fields, scalars, coeffs = data
        ref = run_time_loop(compile_program(p, grid, backend="jnp_naive"),
                            dict(fields), scalars, coeffs, steps, update)
        for bk in backends:
            ex = compile_program(p, grid, backend=bk, mesh=MESH,
                                 mesh_axes=AXES, steps=steps, update=update)
            assert ex.shard is not None and ex.shard.local_grid == tuple(
                g // 2 for g in grid)
            got = ex(fields, scalars, coeffs)
            for k in ref:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(ref[k]),
                    atol=1e-5, rtol=1e-5,
                    err_msg=f"{p.name}/{k} backend={bk} boundary={bnd}")

# --- steps=4 parity, zero + periodic, pallas and jnp backends ------------
g = (8, 8, 128)
check_loop(pw_advection, pw_advection_update(0.1), g, pw_data(g),
           ("pallas", "jnp_fused"))
print("LOOP_PW_OK")
gt = (8, 8, 64)
check_loop(tracer_advection, tracer_advection_update(), gt, tracer_data(gt),
           ("pallas", "jnp_fused"))
print("LOOP_TRACER_OK")

# --- one dispatch: update traced once, second call hits the jit cache ----
p = pw_advection()
fields, scalars, coeffs = pw_data(g)
inner = pw_advection_update(0.1)
traces = [0]
def update(fl, out):
    traces[0] += 1
    return inner(fl, out)
ex = compile_program(p, g, backend="jnp_fused", mesh=MESH, mesh_axes=AXES,
                     steps=5, update=update)
ex(fields, scalars, coeffs)
ex(fields, scalars, coeffs)
assert traces[0] == 1, f"update traced {traces[0]}x, want once"
print("TRACE_ONCE_OK")

# --- 1x1 mesh bit-matches the single-device fused loop -------------------
mesh1 = make_auto_mesh((1,), ("X",))
for bk in ("pallas", "jnp_fused", "jnp_naive"):
    a = compile_program(p, g, backend=bk, steps=4,
                        update=inner)(fields, scalars, coeffs)
    b = compile_program(p, g, backend=bk, mesh=mesh1, mesh_axes=("X",),
                        steps=4, update=inner)(fields, scalars, coeffs)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{bk}/{k}")
print("MESH1_BITMATCH_OK")

# --- single-step sharded parity on all three backends --------------------
ref = compile_program(p, g, backend="jnp_naive")(fields, scalars, coeffs)
for bk in ("pallas", "jnp_fused", "jnp_naive"):
    out = compile_program(p, g, backend=bk, mesh=MESH,
                          mesh_axes=AXES)(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-4, rtol=1e-4, err_msg=f"{bk}/{k}")
print("SINGLE_STEP_OK")

# --- tuned strategy under a mesh: search once, then a pure cache hit -----
calls = [0]
def fake_timer(fn):
    calls[0] += 1
    fn()
    return float(calls[0])
cfg = TuneConfig(timer=fake_timer, max_measured=2, steps=2)
cache = PlanCache(path=None)
ex = compile_program(p, g, backend="jnp_fused", strategy="tuned", mesh=MESH,
                     mesh_axes=AXES, steps=2, update=inner,
                     tune_config=cfg, plan_cache=cache)
n_measured = calls[0]
assert n_measured > 0
compile_program(p, g, backend="jnp_fused", strategy="tuned", mesh=MESH,
                mesh_axes=AXES, steps=2, update=inner,
                tune_config=cfg, plan_cache=cache)
assert calls[0] == n_measured, "second tuned compile must measure nothing"
out = ex(fields, scalars, coeffs)
assert set(out) == {"u", "v", "w"}
print("TUNED_MESH_OK")
print("DIST_LOOP_OK")
"""


@pytest.mark.slow
def test_distributed_fused_loop():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "DIST_LOOP_OK" in r.stdout
