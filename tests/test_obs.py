"""Observability subsystem (repro.obs): tracing, metrics, achieved roofline.

Invariants:
* Spans nest per thread with wall-clock timings; the Chrome export is
  schema-valid ``trace_event`` JSON (every event has ph/ts/pid/tid, and
  complete spans on one track are properly nested, never interleaved).
* Disabled tracing is the no-op singleton — zero records, shared no-op
  span, and numerics bit-identical to an untraced compile.
* MetricsRegistry snapshots are JSON-round-trippable; ServeStats keeps its
  public quantile/occupancy API on top of the registry.
* Tile demotions warn exactly once per explicit-request compile and emit
  typed ChainDemoted/PlaneDemoted events when traced.
* PlanCache counts its own hits/misses; a warm tuned compile is provably
  zero timed runs via the ``tune.timed_runs`` counter.
* ``measure_achieved`` reports a roofline fraction in (0, inf).
"""

import json
import threading
import warnings

import numpy as np
import pytest

from repro.apps import pw_advection, pw_advection_update
from repro.core import (PlanCache, TileDemotionWarning, TuneConfig,
                        compile_program)
from repro.core.frontend import ProgramBuilder
from repro.obs import (MetricsRegistry, NullTracer, Tracer, current_tracer,
                       global_metrics, measure_achieved, resolve_tracer,
                       set_tracer)
from repro.obs.trace import NULL, TRACE_ENV, _reset_for_tests
from repro.serve import ServeStats, StencilEngine, StencilRequest

GRID = (8, 8, 16)


def small_program(name="obs_small"):
    b = ProgramBuilder(name, ndim=3)
    u, = b.inputs("u")
    su = b.output("su")
    b.define(su, u[-1, 0, 0] + u[1, 0, 0] - 2.0 * u[0, 0, 0])
    return b.build()


def data_for(p, grid=GRID, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in p.input_fields()}
    scalars = {s: np.float32(0.05) for s in p.scalars}
    coeffs = {c: np.linspace(0.9, 1.1, grid[ax]).astype(np.float32)
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


def fake_timer():
    calls = {"n": 0}

    def timer(fn):
        i = calls["n"]
        calls["n"] += 1
        return 0.001 * ((i * 7) % 13 + 1)

    return timer, calls


# ---------------------------------------------------------------- tracer

def test_spans_nest_and_carry_attrs():
    tr = Tracer()
    with tr.span("outer", a=1) as sp:
        sp.set(b=2)
        with tr.span("inner"):
            tr.event("tick", k="v")
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["inner", "outer"]  # close order
    outer = tr.spans("outer")[0]
    inner = tr.spans("inner")[0]
    assert outer["args"] == {"a": 1, "b": 2}
    assert outer["depth"] == 0 and inner["depth"] == 1
    # containment: inner lies inside outer on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    ev = tr.events("tick")[0]
    assert ev["args"] == {"k": "v"} and ev["depth"] == 2


def test_tracer_threads_get_own_stacks_and_tids():
    tr = Tracer()
    done = threading.Event()

    def worker():
        with tr.span("w"):
            done.wait(5)

    t = threading.Thread(target=worker)
    with tr.span("m"):
        t.start()
        done.set()
        t.join()
    m, w = tr.spans("m")[0], tr.spans("w")[0]
    assert m["tid"] != w["tid"]
    assert w["depth"] == 0        # not nested under the main thread's span


def test_emit_typed_event():
    from repro.obs.events import PlanChosen
    tr = Tracer()
    tr.emit(PlanChosen(program="p", backend="pallas", schedule="stream",
                       strategy="auto", roofline_fraction=0.5))
    ev = tr.events("PlanChosen")[0]
    assert ev["args"]["schedule"] == "stream"
    assert ev["args"]["roofline_fraction"] == 0.5


def test_jsonl_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("s", n=1):
        tr.event("e", m=2)
    path = str(tmp_path / "t.jsonl")
    n = tr.export_jsonl(path)
    recs = [json.loads(line) for line in open(path)]
    assert len(recs) == n == 2
    assert {r["kind"] for r in recs} == {"span", "event"}
    assert all(set(("name", "ts", "pid", "tid", "args")) <= set(r)
               for r in recs)


def _validate_chrome(doc):
    """trace_event schema: required keys everywhere, X spans per track
    properly nested (any two either disjoint or contained)."""
    evs = doc["traceEvents"]
    for ev in evs:
        assert set(("ph", "ts", "pid", "tid", "name")) <= set(ev), ev
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0
        else:
            assert ev["ph"] == "i" and ev["s"] == "t"
    by_track = {}
    for ev in evs:
        if ev["ph"] == "X":
            by_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for track in by_track.values():
        track.sort(key=lambda e: (e["ts"], -e["dur"]))
        eps = 1e-3  # us rounding slack
        for a, b in [(a, b) for i, a in enumerate(track)
                     for b in track[i + 1:]]:
            a_end = a["ts"] + a["dur"]
            disjoint = b["ts"] >= a_end - eps
            contained = b["ts"] + b["dur"] <= a_end + eps
            assert disjoint or contained, (a["name"], b["name"])


def test_chrome_export_schema_and_nesting(tmp_path):
    tr = Tracer()
    with tr.span("compile"):
        with tr.span("tune"):
            for i in range(3):
                with tr.span("tune.candidate", i=i):
                    pass
        tr.event("PlanChosen", label="x")
    with tr.span("serve.batch"):
        pass
    path = str(tmp_path / "trace.json")
    n = tr.export_chrome(path)
    doc = json.load(open(path))
    assert n == len(doc["traceEvents"]) == 7
    _validate_chrome(doc)
    # microsecond timestamps, args preserved
    cands = [e for e in doc["traceEvents"] if e["name"] == "tune.candidate"]
    assert sorted(c["args"]["i"] for c in cands) == [0, 1, 2]


def test_null_tracer_is_free_and_cannot_export(tmp_path):
    tr = NullTracer()
    assert not tr.enabled
    s1 = tr.span("a")
    s2 = tr.span("b", k=1)
    assert s1 is s2               # one shared no-op span, no allocation
    with s1 as sp:
        sp.set(x=1)
        sp.event("e")
    tr.event("e")
    tr.emit(object())             # emit never inspects when disabled
    assert tr.records() == []
    with pytest.raises(RuntimeError):
        tr.export_chrome(str(tmp_path / "x.json"))


def test_current_tracer_defaults_to_null_and_active_overrides():
    _reset_for_tests()
    try:
        assert current_tracer() is NULL
        tr = Tracer()
        with tr.active():
            assert current_tracer() is tr
            inner = Tracer()
            with inner.active():
                assert current_tracer() is inner
            assert current_tracer() is tr
        assert current_tracer() is NULL
        set_tracer(tr)
        assert current_tracer() is tr
    finally:
        _reset_for_tests()


def test_trace_env_installs_process_tracer(tmp_path, monkeypatch):
    path = str(tmp_path / "env_trace.json")
    monkeypatch.setenv(TRACE_ENV, path)
    _reset_for_tests()
    try:
        tr = current_tracer()
        assert tr.enabled and isinstance(tr, Tracer)
        assert current_tracer() is tr     # cached after the first check
    finally:
        _reset_for_tests()


def test_resolve_tracer_contract():
    _reset_for_tests()
    try:
        assert resolve_tracer(None) is NULL
        assert resolve_tracer(False) is NULL
        tr = Tracer()
        assert resolve_tracer(tr) is tr
        t = resolve_tracer(True)          # installs a fresh process tracer
        assert t.enabled and current_tracer() is t
        assert resolve_tracer(True) is t  # idempotent once installed
        with pytest.raises(TypeError):
            resolve_tracer("yes")
    finally:
        _reset_for_tests()


# --------------------------------------------------------------- metrics

def test_metrics_registry_instruments_and_snapshot_roundtrip():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(1.5)
    reg.gauge("g").add(0.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 3 and snap["g"] == 2.0
    # p50 index = round(0.5 * 3) = 2 under banker's rounding => 3.0
    assert snap["h"]["count"] == 4 and snap["h"]["p50"] == 3.0
    assert json.loads(json.dumps(snap)) == snap   # JSON round-trip
    assert reg.names() == ["c", "g", "h"]
    reg.reset()
    assert reg.counter("c").value == 0
    assert len(reg.histogram("h")) == 0 and reg.histogram("h").total == 0


def test_metrics_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_histogram_window_cap_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", maxlen=100)
    for v in range(250):
        h.observe(float(v))
    assert len(h) == 100 and h.total == 250
    # window holds 150..249
    assert h.quantile(0.0) == 150.0 and h.quantile(1.0) == 249.0


# ------------------------------------------------------------- ServeStats

def test_servestats_attribute_api_is_registry_backed():
    s = ServeStats()
    s.completed += 1
    s.completed += 2
    s.wall_s += 0.5
    assert s.completed == 3 and s.wall_s == 0.5
    assert s.registry.counter("completed").value == 3
    with pytest.raises(AttributeError):
        s.not_a_metric


def test_servestats_quantiles_on_known_sequences():
    s = ServeStats()
    for ms in [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0]:
        s.record_latency(ms)
    assert s.p50_ms() == 50.0       # round(0.5 * 9) = index 4 (sorted)
    assert s.p99_ms() == 100.0
    assert s.latency_quantile(0.0) == 10.0
    s.reset_latencies()
    assert s.p50_ms() == 0.0 and s.p99_ms() == 0.0


def test_servestats_latency_window_capped_at_4096():
    from repro.serve.stats import LATENCY_WINDOW
    assert LATENCY_WINDOW == 4096
    s = ServeStats()
    for i in range(LATENCY_WINDOW + 500):
        s.record_latency(float(i))
    assert s.snapshot()["latencies"] == LATENCY_WINDOW
    assert s.latency_quantile(0.0) == 500.0    # oldest 500 evicted


def test_servestats_occupancy_and_snapshot_roundtrip():
    s = ServeStats()
    s.batched_requests += 6
    s.padded_slots += 2
    s.exec_hits += 3
    s.exec_misses += 1
    s.completed += 6
    s.wall_s = 2.0
    s.record_latency(12.5)
    assert s.occupancy() == 0.75
    assert s.cache_hit_rate() == 0.75
    assert s.throughput() == 3.0
    snap = s.snapshot()
    assert snap["occupancy"] == 0.75 and snap["latencies"] == 1
    assert json.loads(json.dumps(snap)) == snap


# ----------------------------------------------- demotion warnings/events

def test_time_tile_demotion_warns_exactly_once():
    p = pw_advection(boundary="periodic")   # periodic => chain demotes
    update = pw_advection_update(0.1)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ex = compile_program(p, GRID, backend="pallas", schedule="stream",
                             steps=2, update=update, time_tile=4)
    demos = [x for x in w if issubclass(x.category, TileDemotionWarning)]
    assert len(demos) == 1
    msg = str(demos[0].message)
    assert "time_tile=4" in msg and "effective 1" in msg and "periodic" in msg
    assert ex.plan.stream.time_tile == 1


def test_plane_tile_demotion_warns_exactly_once():
    p = pw_advection()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ex = compile_program(p, GRID, backend="pallas", schedule="stream",
                             plane_tile=64)
    demos = [x for x in w if issubclass(x.category, TileDemotionWarning)]
    assert len(demos) == 1
    assert "plane_tile=64" in str(demos[0].message)
    assert ex.plan.stream.plane_tile == 1


def test_no_warning_when_tiles_legal_or_unrequested():
    p = pw_advection()
    update = pw_advection_update(0.1)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        compile_program(p, GRID, backend="pallas", schedule="stream",
                        steps=2, update=update, time_tile=2)   # legal
        compile_program(p, GRID, backend="pallas", schedule="stream")
    assert not [x for x in w if issubclass(x.category, TileDemotionWarning)]


def test_demotions_emit_typed_events_when_traced():
    tr = Tracer()
    p = pw_advection(boundary="periodic")
    update = pw_advection_update(0.1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", TileDemotionWarning)
        compile_program(p, GRID, backend="pallas", schedule="stream",
                        steps=2, update=update, time_tile=4, trace=tr)
        compile_program(pw_advection(), GRID, backend="pallas",
                        schedule="stream", plane_tile=64, trace=tr)
    chain = tr.events("ChainDemoted")
    plane = tr.events("PlaneDemoted")
    assert chain and chain[0]["args"]["requested"] == 4
    assert chain[0]["args"]["effective"] == 1 and chain[0]["args"]["reason"]
    assert plane and plane[0]["args"]["requested"] == 64
    assert plane[0]["args"]["effective"] == 1


# -------------------------------------------------------- compile tracing

def test_compile_span_and_plan_chosen_event():
    tr = Tracer()
    ex = compile_program(small_program(), GRID, backend="pallas", trace=tr)
    sp = tr.spans("compile")[0]
    assert sp["args"]["program"] == "obs_small"
    assert sp["args"]["backend"] == "pallas" and sp["dur"] >= 0
    assert sp["args"]["schedule"] in ("block", "stream")
    chosen = tr.events("PlanChosen")
    assert len(chosen) == 1
    assert chosen[0]["args"]["program"] == "obs_small"
    assert ex.plan is not None


def test_explicit_plan_compile_emits_no_plan_chosen():
    from repro.core.schedule import auto_plan
    p = small_program()
    plan = auto_plan(p, GRID, backend="pallas")
    tr = Tracer()
    compile_program(p, GRID, backend="pallas", plan=plan, trace=tr)
    assert tr.events("PlanChosen") == []   # nothing was chosen: plan given
    assert tr.spans("compile")             # ...but the span still records


def test_untraced_compile_records_nothing_and_matches_traced():
    _reset_for_tests()
    p = small_program()
    fields, scalars, coeffs = data_for(p)
    ex0 = compile_program(p, GRID, backend="pallas")
    tr = Tracer()
    ex1 = compile_program(p, GRID, backend="pallas", trace=tr)
    a = ex0(fields, scalars, coeffs)["su"]
    b = ex1(fields, scalars, coeffs)["su"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert current_tracer() is NULL        # no ambient leak from trace=


def test_compile_metrics_counters_advance():
    m = global_metrics()
    c0 = m.counter("compile.compiles").value
    s0 = m.counter("compile.stream_lowerings").value
    compile_program(small_program(), GRID, backend="pallas",
                    schedule="stream")
    assert m.counter("compile.compiles").value == c0 + 1
    assert m.counter("compile.stream_lowerings").value == s0 + 1


# --------------------------------------------------- PlanCache + tuner obs

def test_plan_cache_counts_its_own_hits_and_misses():
    cache = PlanCache(path=None)
    assert cache.lookup("k") is None
    cache.store("k", {"v": 1})
    assert cache.lookup("k") == {"v": 1}
    assert cache.lookup("other") is None
    assert cache.hits == 1 and cache.misses == 2
    assert cache.metrics.snapshot() == {"hits": 1, "misses": 2}


def test_warm_tuned_compile_is_zero_timed_runs_by_counter(tmp_path):
    """Satellite: the zero-timed-run warm-hit guarantee is now observable
    through the ``tune.timed_runs`` counter and the cache's own hit/miss
    counters — no timer monkeypatching needed to prove it."""
    p = pw_advection()
    path = str(tmp_path / "plans.json")
    update = pw_advection_update(0.1)
    timer, _ = fake_timer()
    cfg = TuneConfig(steps=2, max_measured=3, timer=timer)
    m = global_metrics()

    cache1 = PlanCache(path=path)
    compile_program(p, GRID, backend="jnp_fused", strategy="tuned", steps=2,
                    update=update, tune_config=cfg, plan_cache=cache1)
    assert m.counter("tune.timed_runs").value > 0
    assert cache1.misses >= 1 and cache1.hits == 0

    cache2 = PlanCache(path=path)       # fresh object: through the file
    t0 = m.counter("tune.timed_runs").value
    r0 = m.counter("tune.runs").value
    compile_program(p, GRID, backend="jnp_fused", strategy="tuned", steps=2,
                    update=update, tune_config=cfg, plan_cache=cache2)
    assert m.counter("tune.timed_runs").value == t0   # zero timed runs
    assert m.counter("tune.runs").value == r0         # no search at all
    assert cache2.hits == 1 and cache2.misses == 0


def test_tuned_compile_trace_has_candidates_and_fraction():
    tr = Tracer()
    timer, _ = fake_timer()
    compile_program(pw_advection(), GRID, backend="pallas",
                    strategy="tuned", steps=2,
                    update=pw_advection_update(0.1),
                    tune_config=TuneConfig(steps=2, max_measured=3,
                                           timer=timer),
                    plan_cache=PlanCache(path=None), trace=tr)
    cands = tr.spans("tune.candidate")
    assert len(cands) >= 2
    assert all("label" in c["args"] for c in cands)
    assert tr.spans("tune")
    assert tr.events("CacheMiss")       # tuned_plan lookup missed
    chosen = tr.events("PlanChosen")
    assert chosen
    rf = chosen[0]["args"]["roofline_fraction"]
    assert rf is not None and 0 < rf < float("inf")


def test_tune_record_carries_roofline_fraction():
    from repro.core import tune_plan
    timer, _ = fake_timer()
    res = tune_plan(pw_advection(), GRID, backend="jnp_fused",
                    update=pw_advection_update(0.1),
                    config=TuneConfig(steps=2, max_measured=3, timer=timer),
                    cache=PlanCache(path=None))
    rf = res.record["roofline_fraction"]
    assert rf is not None and 0 < rf < float("inf")


# ------------------------------------------------------- achieved roofline

def test_measure_achieved_fraction_in_open_interval():
    p = small_program()
    fields, scalars, coeffs = data_for(p)
    ex = compile_program(p, GRID, backend="pallas")
    tr = Tracer()
    res = measure_achieved(ex, fields, scalars, coeffs, warmup=1, repeats=1,
                           tracer=tr)
    assert 0 < res.achieved_fraction < float("inf")
    assert res.steps == 1 and res.points == float(np.prod(GRID))
    assert res.steps_per_sec > 0 and res.bytes_moved > 0
    d = res.to_dict()
    assert json.loads(json.dumps(d)) == d
    sp = tr.spans("roofline.achieved")[0]
    assert sp["args"]["roofline_fraction"] == res.achieved_fraction


def test_achieved_fraction_degenerate_inputs():
    from repro.obs import achieved_fraction
    assert achieved_fraction(1.0, 0.0) == 0.0
    assert achieved_fraction(0.0, 1.0) == 0.0
    assert achieved_fraction(2.0, 4.0) == 0.5


# ------------------------------------------------------------ serve tracing

def test_engine_traces_batches_and_caches():
    p = pw_advection()
    fields, scalars, coeffs = data_for(p, GRID)
    tr = Tracer()
    with StencilEngine(backend="jnp_fused", tracer=tr) as eng:
        for _ in range(2):
            eng.run(StencilRequest(program=p, fields=fields,
                                   scalars=scalars, coeffs=coeffs))
    assert len(tr.spans("serve.batch")) >= 1
    assert len(tr.spans("serve.build_executor")) == 1
    names = {e["args"].get("cache") for e in tr.events("CacheMiss")}
    assert "executor" in names
    assert tr.events("CacheHit")        # the second request was warm


def test_engine_eviction_emits_event_and_counter():
    pa, pb = small_program("obs_ev_a"), small_program("obs_ev_b")
    fa, sa, ca = data_for(pa)
    tr = Tracer()
    with StencilEngine(backend="jnp_fused", max_executors=1,
                       tracer=tr) as eng:
        eng.run(StencilRequest(program=pa, fields=fa, scalars=sa, coeffs=ca))
        eng.run(StencilRequest(program=pb, fields=fa, scalars=sa, coeffs=ca))
        assert eng.stats.evictions == 1
    evs = tr.events("ExecutorEvicted")
    assert len(evs) == 1 and evs[0]["args"]["resident"] == 1


# --------------------------------------------------------------- end-to-end

def test_end_to_end_trace_compile_tune_serve(tmp_path):
    """The acceptance shape of examples/trace_compile.py: one tracer sees
    the tuned compile (>= 2 candidates), the serve batch, a PlanChosen with
    a finite positive roofline fraction — and exports valid Chrome JSON."""
    p = pw_advection()
    fields, scalars, coeffs = data_for(p, GRID)
    tr = Tracer()
    timer, _ = fake_timer()
    compile_program(p, GRID, backend="pallas", strategy="tuned", steps=2,
                    update=pw_advection_update(0.1),
                    tune_config=TuneConfig(steps=2, max_measured=3,
                                           timer=timer),
                    plan_cache=PlanCache(path=None), trace=tr)
    with StencilEngine(backend="jnp_fused", tracer=tr) as eng:
        eng.run(StencilRequest(program=p, fields=fields, scalars=scalars,
                               coeffs=coeffs))
    assert tr.spans("compile")
    assert len(tr.spans("tune.candidate")) >= 2
    assert len(tr.spans("serve.batch")) >= 1
    rfs = [e["args"]["roofline_fraction"] for e in tr.events("PlanChosen")]
    assert any(rf is not None and 0 < rf < float("inf") for rf in rfs)
    path = str(tmp_path / "e2e.json")
    tr.export_chrome(path)
    _validate_chrome(json.load(open(path)))
