"""Spatial unrolling (``plane_tile``) of the stream sweep.

Acceptance invariants:

* advancing P consecutive planes per sweep grid step is numerically
  invisible: 1e-5 parity against ``plane_tile=1`` for both paper kernels
  under zero AND periodic boundaries, single-step and composed with the
  ``time_tile=4`` temporal chain, sweep remainders (``n_steps % P != 0``)
  included;
* legalisation demotes an over-wide sweep (``n_steps < P``) to an
  effective width of 1 with a reason (mirroring ``chain_split_reason``)
  instead of miscompiling;
* ``vmem_cost`` prices the P-widened windows (wider sweep = more VMEM);
* the tuner enumerates ``plane_tiles=(1, 2, 4)`` in both single-step and
  fused-loop modes, and a tuned ``plane_tile`` survives the JSON
  plan-cache round trip into ``strategy="tuned"`` with zero timed runs;
* a stale v3 cache file is a clean miss rewritten at v4, never a crash;
* serving executors with different ``plane_tile`` never share a slot
  (``bucket_fingerprint``).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.apps import pw_advection
from repro.core import (CompileOptions, PlanCache, TuneConfig,
                        compile_program, effective_plane_tile,
                        plan_to_dict, plane_split_reason)
from repro.core.schedule import auto_plan, bucket_fingerprint, vmem_cost
from repro.core.tune import CACHE_SCHEMA_VERSION, cache_key
from test_stream import KERNELS


# ------------------------------------------------------------ parity

@pytest.mark.parametrize("boundary", ["zero", "periodic"])
@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("pt", [2, 4])
def test_plane_tiled_sweep_matches_plane_at_a_time(kernel, boundary, pt):
    """plane_tile=P (P in {2,4}) is numerically invisible for a single
    sweep: the unrolled step computes the same planes the one-plane sweep
    does, remainder tiles (``n_steps % P != 0``) included — the tracer
    grid's 6-plane stream axis leaves a remainder under P=4."""
    prog_fn, _update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    fields, scalars, coeffs = data_fn(grid)
    ex1 = compile_program(p, grid, schedule="stream")
    exP = compile_program(p, grid, options=CompileOptions(
        schedule="stream", plane_tile=pt))
    assert exP.plan.plane_tile == pt          # the request is recorded
    assert exP.plan.stream.plane_tile == pt   # ...and survives legalisation
    r1 = ex1(fields, scalars, coeffs)
    rP = exP(fields, scalars, coeffs)
    for f in r1:
        np.testing.assert_allclose(np.asarray(rP[f]), np.asarray(r1[f]),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("boundary", ["zero", "periodic"])
@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("pt", [2, 4])
def test_plane_tile_composes_with_temporal_chain(kernel, boundary, pt):
    """The PxT tile: plane_tile=P through a time_tile=4 fused loop matches
    the P=1 loop at the same chain depth (periodic / multi-region programs
    demote the chain, not the sweep width — parity must hold either way)."""
    prog_fn, update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    fields, scalars, coeffs = data_fn(grid)
    steps = 8
    ex1 = compile_program(p, grid, options=CompileOptions(
        schedule="stream", steps=steps, update=update, time_tile=4))
    exP = compile_program(p, grid, options=CompileOptions(
        schedule="stream", steps=steps, update=update, time_tile=4,
        plane_tile=pt))
    assert exP.plan.stream.plane_tile == pt
    r1 = ex1(fields, scalars, coeffs)
    rP = exP(fields, scalars, coeffs)
    for f in r1:
        np.testing.assert_allclose(np.asarray(rP[f]), np.asarray(r1[f]),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("kernel,pt", [("pw_advection", 3),
                                       ("tracer_advection", 4)])
def test_plane_tile_sweep_remainder(kernel, pt):
    """n_steps % P != 0: the final (shallower) tile stores only the planes
    that exist — pw's 8-plane axis under P=3 also leaves the output blocks
    misaligned with the sweep tiles (span % P != 0), exercising the
    staging-realignment path."""
    prog_fn, update, data_fn, grid = KERNELS[kernel]
    p = prog_fn()
    assert grid[0] % pt != 0
    fields, scalars, coeffs = data_fn(grid)
    for opts1, optsP in [
        (CompileOptions(schedule="stream"),
         CompileOptions(schedule="stream", plane_tile=pt)),
        (CompileOptions(schedule="stream", steps=5, update=update),
         CompileOptions(schedule="stream", steps=5, update=update,
                        plane_tile=pt)),
    ]:
        r1 = compile_program(p, grid, options=opts1)(fields, scalars, coeffs)
        rP = compile_program(p, grid, options=optsP)(fields, scalars, coeffs)
        for f in r1:
            np.testing.assert_allclose(np.asarray(rP[f]), np.asarray(r1[f]),
                                       atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ legalisation

@pytest.mark.parametrize("boundary", ["zero", "periodic"])
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_plane_tile_demotes_overwide_sweep(kernel, boundary):
    """n_steps < P: a sweep step would span more planes than the domain
    holds — demoted to an effective width of 1 with a reason, mirroring
    ``chain_split_reason``; parity against plane_tile=1 still holds."""
    prog_fn, _update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    small = (3,) + grid[1:]
    fields, scalars, coeffs = data_fn(small)
    exP = compile_program(p, small, options=CompileOptions(
        schedule="stream", plane_tile=4))
    assert exP.plan.plane_tile == 4           # the request survives
    assert exP.plan.stream.plane_tile == 1    # ...the unroll does not
    reason = plane_split_reason(p, 4, small)
    assert reason is not None and "exceeds the stream extent" in reason
    assert effective_plane_tile(p, 4, small) == 1
    # without a grid, legality is undecidable yet: the request stands
    assert plane_split_reason(p, 4) is None
    assert effective_plane_tile(p, 4) == 4
    r1 = compile_program(p, small, schedule="stream")(fields, scalars,
                                                      coeffs)
    rP = exP(fields, scalars, coeffs)
    for f in r1:
        np.testing.assert_allclose(np.asarray(rP[f]), np.asarray(r1[f]),
                                   atol=1e-5, rtol=1e-5)


def test_plane_tile_validation():
    p = pw_advection()
    grid = (8, 8, 32)
    with pytest.raises(ValueError):
        compile_program(p, grid, schedule="stream", plane_tile=0)
    # spatial unrolling widens the stream sweep: block tiles have none
    with pytest.raises(ValueError, match="stream"):
        auto_plan(p, grid, plane_tile=2)
    with pytest.raises(ValueError, match="stream"):
        dataclasses.replace(auto_plan(p, grid), plane_tile=2)
    # retargeting a plane-tiled stream plan to "block" resets the width
    ex = compile_program(p, grid, options=CompileOptions(
        backend="pallas", plan=auto_plan(p, grid, schedule="stream",
                                         plane_tile=4),
        schedule="block"))
    assert ex.plan.plane_tile == 1


def test_vmem_cost_prices_plane_width():
    """A P-wide sweep step holds P extra input planes per window and the
    P output planes (plus staging realignment) in VMEM — the cost model
    must see that, or the tuner would admit widths that cannot fit."""
    p = pw_advection()
    grid = (8, 8, 32)
    costs = [vmem_cost(p, auto_plan(p, grid, schedule="stream",
                                    plane_tile=pt, vmem_budget=1 << 40),
                       grid)
             for pt in (1, 2, 4)]
    assert costs[0] < costs[1] < costs[2]


# ------------------------------------------------------------ tuner + cache

@pytest.mark.parametrize("with_loop", [True, False])
def test_tuner_enumerates_plane_tiles(with_loop):
    """plane_tiles=(1,2,4) are distinct stream candidates in BOTH modes —
    unlike the temporal chain, a wider sweep step needs no update rule."""
    from repro.core.tune import _candidates
    cfg = TuneConfig(steps=4, timer=lambda fn: 1.0)
    cands = _candidates(pw_advection(), (8, 8, 32), "pallas", True,
                        "float32", cfg, with_loop=with_loop)
    eff = {c.plan.stream.plane_tile for c in cands
           if c.plan.schedule == "stream" and c.plan.stream is not None}
    assert {1, 2, 4} <= eff


def test_tuned_plane_tile_round_trips_through_plan_cache(tmp_path):
    """A tuned plane-tiled plan survives the on-disk JSON cache: the stored
    ``plane_tile`` deserialises into ``strategy="tuned"`` with zero timed
    runs and drives the unrolled lowering to the same numbers."""
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    plan = auto_plan(p, grid, schedule="stream", plane_tile=4)
    assert plan.stream.plane_tile == 4
    path = str(tmp_path / "plan_cache.json")
    PlanCache(path=path).store(
        cache_key(p, grid, "pallas", True, "float32", "loop"),
        {"plan": plan_to_dict(plan), "carry_write": "repad"})

    def no_timer(fn):                        # a timed run would be a bug
        raise AssertionError("cache hit must not measure")

    ex = compile_program(p, grid, options=CompileOptions(
        strategy="tuned", steps=4, update=update,
        tune_config=TuneConfig(timer=no_timer),
        plan_cache=PlanCache(path=path)))    # fresh object: real file read
    assert ex.plan.schedule == "stream"
    assert ex.plan.plane_tile == 4 and ex.plan.stream.plane_tile == 4
    ref = compile_program(p, grid, schedule="stream", steps=4,
                          update=update)(fields, scalars, coeffs)
    got = ex(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


def test_stale_v3_cache_is_clean_miss_and_rewritten(tmp_path):
    """A v3-era cache file (pre-plane_tile) never serves entries — its
    records lack the new field, so decoding them silently would pin every
    tuned plan to an implicit width.  Lookup is a clean miss; the next
    store rewrites the file at v4."""
    assert CACHE_SCHEMA_VERSION == 4
    path = str(tmp_path / "plans.json")
    p = pw_advection()
    grid = (8, 8, 32)
    key = cache_key(p, grid, "pallas", True, "float32", "loop")
    plan_doc = plan_to_dict(auto_plan(p, grid, schedule="stream"))
    plan_doc.pop("plane_tile", None)          # a genuine v3 record
    with open(path, "w") as f:
        json.dump({"version": 3, "entries": {
            key: {"plan": plan_doc, "carry_write": "repad"}}}, f)
    cache = PlanCache(path=path)
    assert cache.lookup(key) is None          # stale version = miss
    cache.store(key, {"plan": plan_to_dict(auto_plan(p, grid)),
                      "carry_write": "repad"})
    doc = json.load(open(path))
    assert doc["version"] == CACHE_SCHEMA_VERSION
    assert key in doc["entries"]


def test_bucket_fingerprint_distinguishes_plane_tile():
    p = pw_advection()
    keys = {bucket_fingerprint(p, (16, 16, 16), backend="pallas",
                               schedule="stream", plane_tile=pt)
            for pt in (None, 1, 2, 4)}
    assert len(keys) == 4


# ------------------------------------------------------------ mesh

MESH_SCRIPT = r"""
import numpy as np, jax
from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import CompileOptions, compile_program
from repro.dist.sharding import make_auto_mesh

rng = np.random.default_rng(11)
assert jax.device_count() == 2
MESH = make_auto_mesh((1, 2), ("X", "Y"))
AXES = ("X", "Y", None)

def pw_data(grid):
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs

def tracer_data(grid):
    fields = {
        "t": rng.normal(size=grid).astype(np.float32) + 15.0,
        "un": rng.normal(size=grid).astype(np.float32) * 0.2,
        "vn": rng.normal(size=grid).astype(np.float32) * 0.2,
        "wn": rng.normal(size=grid).astype(np.float32) * 0.05,
        "e3t": np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0,
        "msk": (rng.uniform(size=grid) > 0.05).astype(np.float32)}
    scalars = {"rdt": np.float32(0.05), "zeps": np.float32(1e-6)}
    coeffs = {"ztfreez": np.full(grid[2], -1.8, np.float32)}
    return fields, scalars, coeffs

CASES = [("pw", pw_advection, pw_advection_update, pw_data, (8, 8, 32)),
         ("tracer", tracer_advection, tracer_advection_update, tracer_data,
          (6, 8, 32))]
for name, prog_fn, update_fn, data_fn, grid in CASES:
    for bnd in ("zero", "periodic"):
        p = prog_fn(boundary=bnd)
        fields, scalars, coeffs = data_fn(grid)
        upd = update_fn()
        r1 = compile_program(p, grid, options=CompileOptions(
            schedule="stream", steps=8, update=upd, time_tile=4,
            mesh=MESH, mesh_axes=AXES))(fields, scalars, coeffs)
        for pt in (2, 4):
            exP = compile_program(p, grid, options=CompileOptions(
                schedule="stream", steps=8, update=upd, time_tile=4,
                plane_tile=pt, mesh=MESH, mesh_axes=AXES))
            assert exP.plan.plane_tile == pt
            rP = exP(fields, scalars, coeffs)
            for k in r1:
                np.testing.assert_allclose(
                    np.asarray(rP[k]), np.asarray(r1[k]),
                    atol=1e-5, rtol=1e-5,
                    err_msg=f"{name}/{bnd}/P={pt}/{k}")
print("PLANE_TILE_MESH_OK")
"""


@pytest.mark.slow
def test_plane_tile_under_mesh():
    """PR acceptance: plane_tile in {2, 4} composed with time_tile=4 and a
    1x2 mesh matches plane_tile=1 to 1e-5 for both apps, both boundaries.
    Subprocess so the simulated-device override never leaks into other
    tests."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "PLANE_TILE_MESH_OK" in r.stdout
