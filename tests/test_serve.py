"""Stencil serving engine tests: bucketing exactness, executor caching
(zero re-traces warm), batching, the async front, and plan-record reuse."""

import queue
import time

import numpy as np
import pytest

from repro import hw
from repro.apps.advection import pw_advection, pw_advection_update
from repro.core.pipeline import compile_program
from repro.core.schedule import (PLAN_SCHEMA_VERSION, bucket_for,
                                 program_reach, quantize_extent)
from repro.core.tune import PlanCache, make_serve_record, read_serve_record
from repro.serve import (StencilEngine, StencilRequest, crop, embed_coeff,
                         embed_field, serving_program, size_scalar_names)

RNG = np.random.default_rng(7)


def make_data(p, grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in p.input_fields()}
    scalars = {s: 0.05 for s in p.scalars}
    coeffs = {c: (np.abs(rng.normal(size=(grid[ax],))) + 0.5
                  ).astype(np.float32)
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


def make_request(p, grid, seed=0, steps=3, dt=0.01, timeout=None):
    fields, scalars, coeffs = make_data(p, grid, seed)
    return StencilRequest(program=p, fields=fields, scalars=scalars,
                          coeffs=coeffs, steps=steps,
                          update=pw_advection_update(dt),
                          update_key=f"pw/dt={dt}", timeout=timeout)


def reference(p, grid, req, backend="jnp_fused"):
    ex = compile_program(p, grid, backend=backend, steps=req.steps,
                         update=req.update)
    return ex(req.fields, req.scalars, req.coeffs)


# --------------------------------------------------------------------------
# bucketing units
# --------------------------------------------------------------------------

def test_quantize_extent_policy():
    # below the quantum: next power of two
    assert quantize_extent(3) == 4
    assert quantize_extent(17) == 32
    assert quantize_extent(100, lane_axis=True) == 128
    # at/above: align to the quantum
    assert quantize_extent(33) == 64
    assert quantize_extent(64) == 64
    assert quantize_extent(129, lane_axis=True) == 256
    assert quantize_extent(128, lane_axis=True) == 128
    with pytest.raises(ValueError):
        quantize_extent(0)


def test_bucket_for_keeps_reach_clearance():
    p = pw_advection()
    reach = program_reach(p)
    spec = bucket_for(p, (10, 12, 20))
    for a in range(3):
        lo, hi = int(reach[a, 0]), int(reach[a, 1])
        assert spec.offset[a] == lo
        assert spec.bucket[a] >= spec.grid[a] + lo + hi
    # lane axis quantised to the lane width once big enough
    big = bucket_for(p, (10, 12, hw.LANE))
    assert big.bucket[-1] % hw.LANE == 0


def test_grids_share_buckets():
    p = pw_advection()
    a = bucket_for(p, (8, 8, 16))
    b = bucket_for(p, (7, 8, 18))
    assert a.bucket == b.bucket and a.offset == b.offset
    assert a.grid != b.grid


def test_serving_program_appends_size_scalars_idempotently():
    p = pw_advection()
    sp = serving_program(p)
    assert sp.scalars == p.scalars + size_scalar_names(3)
    assert serving_program(sp) is sp            # idempotent
    assert p.scalars == ["tcx", "tcy"]          # original untouched
    sp.validate()


def test_embed_crop_roundtrip():
    p = pw_advection()
    spec = bucket_for(p, (5, 6, 9))
    x = RNG.normal(size=(5, 6, 9)).astype(np.float32)
    for bnd in ("zero", "periodic"):
        e = embed_field(x, spec, bnd)
        assert e.shape == spec.bucket
        np.testing.assert_array_equal(crop(e, spec), x)
    # zero embedding really is zero outside the interior
    ez = embed_field(x, spec, "zero")
    ez[spec.interior()] = 0
    assert not ez.any()
    # periodic embedding wraps: one cell left of the interior == last cell
    ep = embed_field(x, spec, "periodic")
    o = spec.offset
    np.testing.assert_array_equal(ep[o[0] - 1, o[1]:o[1] + 6, o[2]:o[2] + 9],
                                  x[-1])


def test_embed_coeff_modes():
    p = pw_advection()
    spec = bucket_for(p, (5, 6, 9))
    c = np.arange(9, dtype=np.float32) + 1
    z = embed_coeff(c, 2, spec, "zero")
    assert z.shape == (spec.bucket[2],)
    np.testing.assert_array_equal(z[spec.offset[2]:spec.offset[2] + 9], c)
    assert z.sum() == c.sum()
    w = embed_coeff(c, 2, spec, "periodic")
    np.testing.assert_array_equal(
        w, c[(np.arange(spec.bucket[2]) - spec.offset[2]) % 9])


# --------------------------------------------------------------------------
# exactness: bucketed execution == direct compile at the true grid
# --------------------------------------------------------------------------

@pytest.mark.parametrize("boundary", ["zero", "periodic"])
@pytest.mark.parametrize("backend", ["jnp_fused", "pallas"])
def test_bucketed_fused_loop_matches_direct(boundary, backend):
    p = pw_advection(boundary=boundary)
    grid = (6, 7, 12)
    req = make_request(p, grid, seed=3, steps=3)
    with StencilEngine(backend=backend, window_s=0.0) as eng:
        res = eng.run(req, timeout=300)
    ref = reference(p, grid, req, backend=backend)
    assert set(res.outputs) == set(ref)
    for k in ref:
        np.testing.assert_allclose(res.outputs[k], np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


def test_bucketed_single_apply_matches_direct():
    p = pw_advection(boundary="periodic")
    grid = (5, 9, 14)
    fields, scalars, coeffs = make_data(p, grid, seed=11)
    req = StencilRequest(program=p, fields=fields, scalars=scalars,
                         coeffs=coeffs)
    with StencilEngine(backend="jnp_fused", window_s=0.0) as eng:
        res = eng.run(req, timeout=300)
    ref = compile_program(p, grid, backend="jnp_fused")(fields, scalars,
                                                        coeffs)
    for k in ref:
        np.testing.assert_allclose(res.outputs[k], np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


def test_boundary_override_on_request():
    p = pw_advection()                       # declared zero
    grid = (6, 6, 12)
    req = make_request(p, grid, seed=5)
    req.boundary = "periodic"
    with StencilEngine(window_s=0.0) as eng:
        res = eng.run(req, timeout=300)
    ref = reference(p.with_boundary("periodic"), grid, req)
    for k in ref:
        np.testing.assert_allclose(res.outputs[k], np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# executor cache: warm requests re-trace nothing
# --------------------------------------------------------------------------

def test_warm_requests_zero_retraces():
    p = pw_advection()
    with StencilEngine(window_s=0.0) as eng:
        eng.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        assert eng.stats.traces >= 1 and eng.stats.compiles == 1
        warm = eng.stats.traces
        # same grid again, and a *different* grid in the same bucket
        eng.run(make_request(p, (8, 8, 16), seed=1), timeout=300)
        eng.run(make_request(p, (7, 8, 18), seed=2), timeout=300)
        assert bucket_for(serving_program(p), (7, 8, 18)).bucket == \
            bucket_for(serving_program(p), (8, 8, 16)).bucket
        assert eng.stats.traces == warm, "warm request re-traced the update"
        assert eng.stats.compiles == 1
        assert eng.stats.exec_hits == 2 and eng.stats.exec_misses == 1
        assert eng.stats.cache_hit_rate() > 0


def test_distinct_buckets_get_distinct_executors():
    p = pw_advection()
    with StencilEngine(window_s=0.0) as eng:
        eng.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        eng.run(make_request(p, (8, 8, 40), seed=0), timeout=300)
        assert eng.stats.compiles == 2


# --------------------------------------------------------------------------
# batching + async front
# --------------------------------------------------------------------------

def test_same_bucket_requests_batch_together():
    p = pw_advection()
    reqs = [make_request(p, g, seed=i)
            for i, g in enumerate([(8, 8, 16), (7, 7, 15), (7, 8, 18)])]
    eng = StencilEngine(window_s=0.5, max_batch=4, autostart=False)
    futs = [eng.submit(r) for r in reqs]
    eng.start()
    try:
        results = [f.result(300) for f in futs]
        assert {r.batch_size for r in results} == {3}
        assert eng.stats.batches == 1
        assert eng.stats.padded_slots == 1          # 3 padded to 4
        assert 0 < eng.stats.occupancy() < 1
        # every answer still matches its own direct compile
        for req, res in zip(reqs, results):
            ref = reference(p, req.grid(), req)
            for k in ref:
                np.testing.assert_allclose(res.outputs[k],
                                           np.asarray(ref[k]),
                                           atol=1e-5, rtol=1e-5)
    finally:
        eng.close()


def test_mixed_shape_traffic_end_to_end():
    p = pw_advection(boundary="periodic")
    grids = [(8, 8, 16), (6, 7, 14), (8, 8, 24), (5, 8, 16), (8, 8, 16)]
    reqs = [make_request(p, g, seed=10 + i) for i, g in enumerate(grids)]
    with StencilEngine(window_s=0.05, max_batch=4) as eng:
        results = eng.map(reqs, timeout=300)
        for req, res in zip(reqs, results):
            ref = reference(p, req.grid(), req)
            for k in ref:
                np.testing.assert_allclose(res.outputs[k],
                                           np.asarray(ref[k]),
                                           atol=1e-5, rtol=1e-5)
        s = eng.stats
        assert s.completed == len(grids) and s.failed == 0
        assert s.cache_hit_rate() > 0
        assert s.throughput() > 0 and s.p99_ms() >= s.p50_ms() > 0


def test_bounded_queue_backpressure():
    p = pw_advection()
    eng = StencilEngine(queue_depth=2, autostart=False)
    eng.submit(make_request(p, (8, 8, 16)))
    eng.submit(make_request(p, (8, 8, 16)))
    with pytest.raises(queue.Full):
        eng.submit(make_request(p, (8, 8, 16)))
    eng.close()
    assert eng.stats.failed == 2               # drained on close


def test_request_timeout_expires_in_queue():
    p = pw_advection()
    eng = StencilEngine(autostart=False)
    fut = eng.submit(make_request(p, (8, 8, 16), timeout=0.01))
    time.sleep(0.05)
    eng.start()
    try:
        with pytest.raises(TimeoutError):
            fut.result(60)
        assert eng.stats.timeouts == 1
    finally:
        eng.close()


def test_submit_validation():
    p = pw_advection()
    eng = StencilEngine(autostart=False)
    fields, scalars, coeffs = make_data(p, (8, 8, 16))
    with pytest.raises(ValueError, match="steps and update"):
        eng.submit(StencilRequest(program=p, fields=fields, scalars=scalars,
                                  coeffs=coeffs, steps=3))
    with pytest.raises(ValueError, match="missing input fields"):
        eng.submit(StencilRequest(program=p, fields={"u": fields["u"]},
                                  scalars=scalars, coeffs=coeffs))
    with pytest.raises(ValueError, match="missing scalars"):
        eng.submit(StencilRequest(program=p, fields=fields, coeffs=coeffs))
    eng.close()


# --------------------------------------------------------------------------
# plan-record persistence
# --------------------------------------------------------------------------

def test_serve_record_reused_across_engines(tmp_path):
    cache_path = str(tmp_path / "plans.json")
    p = pw_advection()
    req = make_request(p, (8, 8, 16), seed=0)
    with StencilEngine(window_s=0.0,
                       plan_cache=PlanCache(cache_path)) as a:
        ra = a.run(req, timeout=300)
        assert a.stats.plan_misses == 1 and a.stats.plan_hits == 0
    # a fresh engine (fresh process stand-in) rebuilds from the record:
    # zero planning, and the same answer
    with StencilEngine(window_s=0.0,
                       plan_cache=PlanCache(cache_path)) as b:
        rb = b.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        assert b.stats.plan_hits == 1 and b.stats.plan_misses == 0
    for k in ra.outputs:
        np.testing.assert_array_equal(ra.outputs[k], rb.outputs[k])


def test_stale_schema_serve_record_misses_cleanly(tmp_path):
    cache_path = str(tmp_path / "plans.json")
    p = pw_advection()
    req = make_request(p, (8, 8, 16), seed=0)
    cache = PlanCache(cache_path)
    eng = StencilEngine(window_s=0.0, plan_cache=cache, autostart=False)
    _, spec, key = eng.describe(req)
    ex = compile_program(serving_program(p), spec.bucket,
                         backend="jnp_fused")
    rec = make_serve_record(ex.plan, "repad", spec.bucket, req.steps)
    assert read_serve_record(rec) is not None
    rec["schema"] = PLAN_SCHEMA_VERSION - 1          # written by an old build
    assert read_serve_record(rec) is None
    cache.store(key, rec)
    eng.start()
    try:
        res = eng.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        assert eng.stats.plan_misses == 1 and eng.stats.plan_hits == 0
        ref = reference(p, (8, 8, 16), req)
        for k in ref:
            np.testing.assert_allclose(res.outputs[k], np.asarray(ref[k]),
                                       atol=1e-5, rtol=1e-5)
        # the rebuild overwrote the stale record at the current schema
        assert read_serve_record(cache.lookup(key)) is not None
    finally:
        eng.close()


# --------------------------------------------------------------------------
# LRU executor cap + CompileOptions / mesh plumbing
# --------------------------------------------------------------------------

def test_executor_lru_evicts_coldest():
    p = pw_advection()
    grids = [(8, 8, 16), (8, 8, 40), (8, 8, 70)]      # three distinct buckets
    with StencilEngine(window_s=0.0, max_executors=2) as eng:
        eng.run(make_request(p, grids[0], seed=0), timeout=300)
        eng.run(make_request(p, grids[1], seed=0), timeout=300)
        assert eng.stats.evictions == 0 and len(eng._executors) == 2
        # touch bucket 0 so bucket 1 is the coldest, then overflow
        eng.run(make_request(p, grids[0], seed=1), timeout=300)
        eng.run(make_request(p, grids[2], seed=0), timeout=300)
        assert eng.stats.evictions == 1 and len(eng._executors) == 2
        misses = eng.stats.exec_misses
        # the refreshed bucket survived; the cold one was evicted
        eng.run(make_request(p, grids[0], seed=2), timeout=300)
        assert eng.stats.exec_misses == misses
        eng.run(make_request(p, grids[1], seed=1), timeout=300)
        assert eng.stats.exec_misses == misses + 1    # rebuilt after eviction
        assert eng.stats.evictions == 2
        assert eng.stats.snapshot()["evictions"] == 2


def test_engine_accepts_compile_options():
    from repro.core.pipeline import CompileOptions

    # options seeds every knob the caller left at its engine default
    eng = StencilEngine(options=CompileOptions(schedule="block",
                                               dtype="float32",
                                               interpret=False),
                        autostart=False)
    assert eng.schedule == "block" and eng.interpret is False
    # a knob moved off its engine default that disagrees is an error
    with pytest.raises(ValueError, match="dtype"):
        StencilEngine(dtype="bfloat16",
                      options=CompileOptions(dtype="float64"),
                      autostart=False)
    # mesh= without mesh_axes= is rejected up front
    from repro.dist.sharding import make_auto_mesh
    with pytest.raises(ValueError, match="mesh_axes"):
        StencilEngine(mesh=make_auto_mesh((1,), ("X",)), autostart=False)


def test_engine_mesh_topology_keys_executors():
    """The same request served under a mesh and locally must occupy
    distinct executor-table entries (and the sharded answer must agree
    with the local one — a 1x1 mesh runs on the single default device)."""
    from repro.dist.sharding import make_auto_mesh
    p = pw_advection()
    req = make_request(p, (8, 8, 16), seed=0)
    mesh = make_auto_mesh((1,), ("X",))
    with StencilEngine(window_s=0.0, mesh=mesh,
                       mesh_axes=("X", None, None)) as sharded, \
            StencilEngine(window_s=0.0) as local:
        _, _, ks = sharded.describe(req)
        _, _, kl = local.describe(req)
        assert ks != kl and "mesh=X:1" in ks and "mesh=none" in kl
        rs = sharded.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        rl = local.run(make_request(p, (8, 8, 16), seed=0), timeout=300)
        for k in rl.outputs:
            np.testing.assert_allclose(rs.outputs[k], rl.outputs[k],
                                       atol=1e-5, rtol=1e-5)


def test_engine_rejects_periodic_fused_under_sharded_mesh():
    # subprocess: building an actually-sharded mesh needs >= 2 devices
    import os
    import subprocess
    import sys
    script = r"""
import numpy as np
from repro.apps.advection import pw_advection, pw_advection_update
from repro.dist.sharding import make_auto_mesh
from repro.serve import StencilEngine, StencilRequest
p = pw_advection(boundary="periodic")
grid = (8, 8, 16)
rng = np.random.default_rng(0)
req = StencilRequest(
    program=p,
    fields={f: rng.normal(size=grid).astype(np.float32) for f in ("u", "v", "w")},
    scalars={s: 0.05 for s in p.scalars},
    coeffs={c: np.ones(grid[ax], np.float32) for c, ax in p.coeffs.items()},
    steps=3, update=pw_advection_update(), update_key="pw")
eng = StencilEngine(mesh=make_auto_mesh((2,), ("X",)),
                    mesh_axes=("X", None, None), autostart=False)
try:
    eng.describe(req)
    raise SystemExit("periodic fused request under a sharded mesh not rejected")
except ValueError as e:
    assert "periodic" in str(e), e
print("PERIODIC_REJECT_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "PERIODIC_REJECT_OK" in r.stdout
