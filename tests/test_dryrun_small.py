"""Dry-run machinery on a reduced mesh (8 fake devices, subprocess).

The production dry-run (512 devices, full configs) runs via
``python -m repro.launch.dryrun``; this test proves the same build_step /
input_specs / sharding-rules path lowers and compiles for every workload
kind and representative arch families on a (2, 4) mesh with smoke configs.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.dist.sharding import ShardingRules, make_auto_mesh
from repro.launch.specs import build_step
from repro.analysis.roofline import parse_collectives

mesh = make_auto_mesh((2, 4), ("data", "model"))

CASES = [
    ("h2o_danube_1_8b", ShapeConfig("train", 64, 8, "train"), "train"),
    ("mixtral_8x7b", ShapeConfig("train", 64, 8, "train"), "train"),
    ("gemma3_1b", ShapeConfig("prefill", 64, 8, "prefill"), "serve"),
    ("hymba_1_5b", ShapeConfig("decode", 64, 8, "decode"), "serve"),
    ("xlstm_350m", ShapeConfig("decode", 64, 8, "decode"), "serve"),
    ("whisper_small", ShapeConfig("train", 64, 8, "train"), "train"),
    ("nemotron_4_340b", ShapeConfig("decode", 64, 8, "decode"), "serve"),
]

for arch, shape, kind in CASES:
    cfg = get_smoke(arch)
    rules = ShardingRules(mesh=mesh, tp="model",
                          fsdp="data" if kind == "train" else None,
                          dp=("data",))
    step, args, in_sh = build_step(cfg, shape, rules)
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per module
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    coll = parse_collectives(compiled.as_text())
    print(f"{arch} {shape.kind}: ok, {len(coll)} collectives")
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "DRYRUN_SMALL_OK" in r.stdout
