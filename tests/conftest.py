import os
import sys

# make tests/strategies.py importable regardless of how pytest is invoked
sys.path.insert(0, os.path.dirname(__file__))
