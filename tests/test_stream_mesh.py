"""Distributed streaming: ``schedule="stream"`` composed with ``mesh=``.

Runs in a subprocess so the 8-device XLA host-platform override never leaks
into other tests.  Asserts the PR-8 acceptance criteria:

* steps=4 stream-under-mesh (2x2, the stream axis itself sharded) matches
  block-under-mesh AND the single-device stream lowering to 1e-5, for
  pw_advection and tracer_advection, zero and periodic boundaries,
  time_tile in {1, 2};
* the fused distributed stream loop is ONE compiled dispatch: repeated
  calls re-trace nothing;
* a degenerate 1x1 mesh bit-matches the local stream lowering;
* ``strategy="tuned"`` under a mesh measures stream candidates, and a
  warm cache serves a stream plan with zero timed runs — the StreamSpec
  surviving the JSON round-trip.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import PlanCache, TuneConfig, auto_plan, compile_program
from repro.core.tune import plan_from_dict, plan_to_dict, tune_plan
from repro.dist.sharding import make_auto_mesh

rng = np.random.default_rng(11)
assert jax.device_count() == 8

GRID = (16, 16, 32)
MESH = make_auto_mesh((2, 2), ("X", "Y"))   # shards the stream axis (0)
AXES = ("X", "Y", None)

def pw_data(grid):
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs

def tracer_data(grid):
    fields = {
        "t": rng.normal(size=grid).astype(np.float32) + 15.0,
        "un": rng.normal(size=grid).astype(np.float32) * 0.2,
        "vn": rng.normal(size=grid).astype(np.float32) * 0.2,
        "wn": rng.normal(size=grid).astype(np.float32) * 0.05,
        "e3t": np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0,
        "msk": (rng.uniform(size=grid) > 0.05).astype(np.float32)}
    scalars = {"rdt": np.float32(0.05), "zeps": np.float32(1e-6)}
    coeffs = {"ztfreez": np.full(grid[2], -1.8, np.float32)}
    return fields, scalars, coeffs

CASES = [("pw", pw_advection, pw_advection_update, pw_data),
         ("tracer", tracer_advection, tracer_advection_update, tracer_data)]

# --- parity sweep: stream+mesh vs block+mesh vs local stream -------------
for name, prog_fn, update_fn, data_fn in CASES:
    for bnd in ("zero", "periodic"):
        p = prog_fn(boundary=bnd)
        fields, scalars, coeffs = data_fn(GRID)
        for tt in (1, 2):
            upd = update_fn()
            got = compile_program(
                p, GRID, schedule="stream", time_tile=tt, steps=4,
                update=upd, mesh=MESH, mesh_axes=AXES)(fields, scalars,
                                                       coeffs)
            blk = compile_program(
                p, GRID, schedule="block", steps=4, update=upd,
                mesh=MESH, mesh_axes=AXES)(fields, scalars, coeffs)
            loc = compile_program(
                p, GRID, schedule="stream", time_tile=tt, steps=4,
                update=upd)(fields, scalars, coeffs)
            for k in loc:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(blk[k]),
                    atol=1e-5, rtol=1e-5,
                    err_msg=f"{name}/{bnd}/T={tt}/{k} vs block-under-mesh")
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(loc[k]),
                    atol=1e-5, rtol=1e-5,
                    err_msg=f"{name}/{bnd}/T={tt}/{k} vs local stream")
print("PARITY_OK")

# --- one dispatch: repeated calls re-trace nothing -----------------------
p = pw_advection(boundary="zero")
fields, scalars, coeffs = pw_data(GRID)
traces = [0]
base = pw_advection_update()
def counted(fields_, outputs, scalars_=None):
    traces[0] += 1
    return base(fields_, outputs)
ex = compile_program(p, GRID, schedule="stream", time_tile=2, steps=4,
                     update=counted, mesh=MESH, mesh_axes=AXES)
out1 = ex(fields, scalars, coeffs)
jax.block_until_ready(list(out1.values()))
n = traces[0]
assert n >= 1
for _ in range(2):
    out = ex(fields, scalars, coeffs)
    jax.block_until_ready(list(out.values()))
assert traces[0] == n, f"warm calls re-traced: {traces[0]} != {n}"
print("TRACE_ONCE_OK")

# --- degenerate 1x1 mesh bit-matches the local stream lowering -----------
mesh1 = make_auto_mesh((1,), ("X",))
upd = pw_advection_update()
g1 = compile_program(p, GRID, schedule="stream", time_tile=2, steps=4,
                     update=upd, mesh=mesh1,
                     mesh_axes=("X", None, None))(fields, scalars, coeffs)
l1 = compile_program(p, GRID, schedule="stream", time_tile=2, steps=4,
                     update=upd)(fields, scalars, coeffs)
for k in l1:
    assert np.array_equal(np.asarray(g1[k]), np.asarray(l1[k])), k
print("BITMATCH_1X1_OK")

# --- tuned under mesh: stream candidates measured; warm cache serves a
# --- stream plan with zero timed runs (StreamSpec JSON round-trip) -------
calls = [0]
def fake_timer(fn):
    calls[0] += 1
    fn()
    return float(calls[0])
cfg = TuneConfig(timer=fake_timer, steps=2, max_measured=8,
                 strategies=("fused",), carry_writes=("repad",),
                 time_tiles=(2,))
with tempfile.TemporaryDirectory() as tmp:
    cache = PlanCache(path=tmp + "/plans.json")
    res = tune_plan(p, GRID, backend="pallas", update=pw_advection_update(),
                    config=cfg, cache=cache, mesh=MESH, mesh_axes=AXES)
    assert calls[0] > 0
    assert any(c.plan.schedule == "stream" for c in res.measured), \
        "no stream candidate measured under the mesh"
    # pin a stream winner into the record, then verify the warm path
    splan = auto_plan(p, GRID, schedule="stream", time_tile=2, steps=2)
    cache.store(res.key, {**res.record, "plan": plan_to_dict(splan),
                          "carry_write": "repad"})
    n_timed = calls[0]
    ex = compile_program(p, GRID, backend="pallas", strategy="tuned",
                         steps=4, update=pw_advection_update(),
                         tune_config=cfg, plan_cache=cache,
                         mesh=MESH, mesh_axes=AXES)
    assert calls[0] == n_timed, "warm tuned compile must measure nothing"
    assert ex.plan.schedule == "stream" and ex.plan.stream is not None
    # the legalised stream geometry survives a JSON round-trip bit-for-bit
    rt = plan_from_dict(plan_to_dict(ex.plan))
    assert rt.schedule == "stream" and rt.stream == ex.plan.stream
    # ...and a fresh cache handle re-reads the stored stream plan from disk
    rec = PlanCache(path=tmp + "/plans.json").lookup(res.key)
    assert plan_from_dict(rec["plan"]).stream == splan.stream
    tuned = ex(fields, scalars, coeffs)
    ref = compile_program(p, GRID, schedule="block", steps=4,
                          update=pw_advection_update(), mesh=MESH,
                          mesh_axes=AXES)(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(tuned[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)
print("TUNED_STREAM_MESH_OK")
print("STREAM_MESH_OK")
"""


@pytest.mark.slow
def test_stream_under_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "STREAM_MESH_OK" in r.stdout
