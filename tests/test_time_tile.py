"""Temporal blocking (``time_tile``) + the consolidated CompileOptions API.

Acceptance invariants:
* chaining T time steps through one stream sweep is numerically invisible:
  1e-5 fused-loop parity against the unchained stream for T in {1, 2, 4}
  for both paper kernels under zero AND periodic boundaries, remainder
  (``steps % T != 0``) included;
* the chain stays one compiled program (the update rule traces once per
  chain stage at compile, never per step or per call);
* legalisation demotes illegal chains to an effective depth of 1 instead
  of miscompiling (multi-region programs, periodic persistent fields);
* the tuner enumerates chained stream candidates and a tuned ``time_tile``
  survives the JSON plan-cache round trip into ``strategy="tuned"``;
* ``vmem_cost`` prices the T-deepened buffers (deeper chain = more VMEM);
* ``CompileOptions`` and loose kwargs are the same API: equal results,
  single validation point, loud conflicts, loud unknown keys; and
  ``adapt_update`` accepts exactly the two documented update signatures.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import pw_advection, pw_advection_update, tracer_advection
from repro.core import (CompileOptions, PlanCache, TuneConfig, adapt_update,
                        chain_split_reason, compile_program,
                        effective_time_tile, lower_to_dataflow,
                        plan_to_dict)
from repro.core.schedule import auto_plan, vmem_cost
from repro.core.tune import cache_key
from test_stream import KERNELS


# ------------------------------------------------------------ parity

@pytest.mark.parametrize("boundary", ["zero", "periodic"])
@pytest.mark.parametrize("kernel", sorted(KERNELS))
@pytest.mark.parametrize("tile", [2, 4])
def test_chained_stream_matches_unchained(kernel, boundary, tile):
    """time_tile=T (T in {1,2,4}) is numerically invisible: the chained
    sweep matches the unchained stream loop to 1e-5.  Periodic boundaries
    and multi-region programs exercise the demote-to-1 fallback — parity
    must hold either way."""
    prog_fn, update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    fields, scalars, coeffs = data_fn(grid)
    steps = 4
    ex1 = compile_program(p, grid, schedule="stream", steps=steps,
                          update=update)
    exT = compile_program(p, grid, options=CompileOptions(
        schedule="stream", steps=steps, update=update, time_tile=tile))
    assert exT.plan.time_tile == tile          # the request is recorded
    assert exT.plan.stream.time_tile in (1, tile)   # effective: legalised
    r1 = ex1(fields, scalars, coeffs)
    rT = exT(fields, scalars, coeffs)
    for f in r1:
        np.testing.assert_allclose(np.asarray(rT[f]), np.asarray(r1[f]),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("steps,tile", [(5, 4), (7, 2), (3, 4)])
def test_chained_stream_remainder_epilogue(steps, tile):
    """steps not divisible by T: the ``steps % T`` remainder runs once
    through a shallower chain after the fused loop (steps < T means the
    loop body never runs at all) — same numbers as the unchained stream."""
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    ex1 = compile_program(p, grid, schedule="stream", steps=steps,
                          update=update)
    exT = compile_program(p, grid, schedule="stream", steps=steps,
                          update=update, time_tile=tile)
    assert exT.plan.stream.time_tile == tile
    r1 = ex1(fields, scalars, coeffs)
    rT = exT(fields, scalars, coeffs)
    for f in r1:
        np.testing.assert_allclose(np.asarray(rT[f]), np.asarray(r1[f]),
                                   atol=1e-5, rtol=1e-5)


def test_chained_update_traces_once_per_stage():
    """The update rule is baked into the kernel between chain stages: it
    traces exactly T times at compile (once per stage), never per step or
    per call."""
    prog_fn, _update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    traces = {"n": 0}

    def counting_update(flds, out):
        traces["n"] += 1
        return {"u": flds["u"] + 0.1 * out["su"],
                "v": flds["v"] + 0.1 * out["sv"],
                "w": flds["w"] + 0.1 * out["sw"]}

    ex = compile_program(p, grid, schedule="stream", steps=8,
                         update=counting_update, time_tile=4)
    assert ex.plan.stream.time_tile == 4
    ex(fields, scalars, coeffs)
    assert traces["n"] == 4                   # one per chain stage
    ex(fields, scalars, coeffs)               # second call: jit cache hit
    assert traces["n"] == 4


# ------------------------------------------------------------ legalisation

def test_chain_demotes_multi_region_and_periodic():
    pw = pw_advection()
    plan = auto_plan(pw, (8, 8, 32), schedule="stream", time_tile=4)
    graph = lower_to_dataflow(pw, plan)
    assert graph.time_tile == 4               # single region, zero boundary
    assert chain_split_reason(pw, [list(r.ops) for r in graph.regions]) \
        is None

    # tracer_advection legalises to multiple stream regions: no chain
    tr = tracer_advection()
    plan = auto_plan(tr, (6, 8, 32), schedule="stream", time_tile=4)
    assert plan.time_tile == 4                # the request survives
    assert plan.stream.time_tile == 1         # ...the chain does not
    graph = lower_to_dataflow(tr, plan)
    reason = chain_split_reason(tr, [list(r.ops) for r in graph.regions])
    assert reason is not None and "region" in reason

    # periodic persistent fields wrap through planes the chain already
    # consumed: demoted
    pwp = pw_advection(boundary="periodic")
    plan = auto_plan(pwp, (8, 8, 32), schedule="stream", time_tile=4)
    assert plan.stream.time_tile == 1
    graph = lower_to_dataflow(pwp, plan)
    regions = [list(r.ops) for r in graph.regions]
    assert "periodic" in chain_split_reason(pwp, regions)
    assert effective_time_tile(pwp, regions, 4) == 1


def test_time_tile_validation():
    p = pw_advection()
    grid = (8, 8, 32)
    update = pw_advection_update(0.1)
    # temporal blocking needs a fused loop to chain updates through
    with pytest.raises(ValueError, match="steps"):
        compile_program(p, grid, schedule="stream", time_tile=4)
    with pytest.raises(ValueError):
        compile_program(p, grid, schedule="stream", steps=4, update=update,
                        time_tile=0)
    # ...and the stream schedule (block tiles have no chain to ride)
    with pytest.raises(ValueError, match="stream"):
        auto_plan(p, grid, time_tile=2)
    with pytest.raises(ValueError, match="stream"):
        dataclasses.replace(auto_plan(p, grid), time_tile=2)


def test_vmem_cost_prices_chain_depth():
    """Deeper chains hold deeper windows, per-stage plane rings, and
    margin-extended temps in VMEM — the cost model must see that, or the
    tuner would admit chains that cannot fit."""
    p = pw_advection()
    grid = (8, 8, 32)
    costs = [vmem_cost(p, auto_plan(p, grid, schedule="stream",
                                    time_tile=t, vmem_budget=1 << 40), grid)
             for t in (1, 2, 4)]
    assert costs[0] < costs[1] < costs[2]


# ------------------------------------------------------------ tuner + cache

def test_tuner_enumerates_chained_stream_candidates():
    from repro.core.tune import _candidates
    cfg = TuneConfig(steps=4, timer=lambda fn: 1.0)
    cands = _candidates(pw_advection(), (8, 8, 32), "pallas", True,
                        "float32", cfg, with_loop=True)
    eff = {c.plan.stream.time_tile for c in cands
           if c.plan.schedule == "stream" and c.plan.stream is not None}
    assert {1, 2, 4} <= eff
    # single-step sweeps never chain: the T variants dedup away
    cands1 = _candidates(pw_advection(), (8, 8, 32), "pallas", True,
                         "float32", cfg, with_loop=False)
    assert all(c.plan.stream.time_tile == 1 for c in cands1
               if c.plan.schedule == "stream" and c.plan.stream is not None)


def test_tuned_time_tile_round_trips_through_plan_cache(tmp_path):
    """A tuned chained plan survives the on-disk JSON cache: the stored
    ``time_tile`` deserialises into ``strategy="tuned"`` with zero timed
    runs and drives the chained lowering to the same numbers."""
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    plan = auto_plan(p, grid, schedule="stream", time_tile=4)
    assert plan.stream.time_tile == 4
    path = str(tmp_path / "plan_cache.json")
    PlanCache(path=path).store(
        cache_key(p, grid, "pallas", True, "float32", "loop"),
        {"plan": plan_to_dict(plan), "carry_write": "repad"})

    def no_timer(fn):                        # a timed run would be a bug
        raise AssertionError("cache hit must not measure")

    ex = compile_program(p, grid, options=CompileOptions(
        strategy="tuned", steps=4, update=update,
        tune_config=TuneConfig(timer=no_timer),
        plan_cache=PlanCache(path=path)))    # fresh object: real file read
    assert ex.plan.schedule == "stream"
    assert ex.plan.time_tile == 4 and ex.plan.stream.time_tile == 4
    ref = compile_program(p, grid, schedule="stream", steps=4,
                          update=update)(fields, scalars, coeffs)
    got = ex(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ CompileOptions

def test_options_and_kwargs_are_the_same_api():
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    opts = CompileOptions(schedule="stream", steps=2, update=update)
    a = compile_program(p, grid, options=opts)(fields, scalars, coeffs)
    b = compile_program(p, grid, schedule="stream", steps=2,
                        update=update)(fields, scalars, coeffs)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # kwargs may refine knobs the options left at their defaults...
    ex = compile_program(p, grid, options=opts, jit=False)
    assert not ex.jitted
    # ...and repeating a knob with the SAME value is harmless
    compile_program(p, grid, options=opts, steps=2)


def test_options_kwarg_conflict_is_loud():
    p = pw_advection()
    opts = CompileOptions(steps=4, update=pw_advection_update(0.1))
    with pytest.raises(ValueError, match="steps"):
        compile_program(p, (8, 8, 32), options=opts, steps=8)
    with pytest.raises(TypeError, match="stepz"):
        compile_program(p, (8, 8, 32), stepz=4)
    with pytest.raises(TypeError, match="CompileOptions"):
        compile_program(p, (8, 8, 32), options={"steps": 4})


# ------------------------------------------------------------ adapt_update

def test_adapt_update_signatures():
    two = adapt_update(lambda flds, outs: {"a": 1})
    assert two({}, {}, {"s": 9}) == {"a": 1}
    three = adapt_update(lambda flds, outs, scal: {"a": scal["s"]})
    assert three({}, {}, {"s": 9}) == {"a": 9}
    assert adapt_update(None) is None
    for bad in (lambda flds: flds,
                lambda a, b, c, d: a):
        with pytest.raises(TypeError) as err:
            adapt_update(bad)
        # the error names the two accepted forms
        assert "(fields, outputs)" in str(err.value)
        assert "(fields, outputs, scalars)" in str(err.value)
