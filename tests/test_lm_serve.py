"""LM serving engine + whisper serve-path tests (repro.models.lm_serve)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import ServeEngine, init_lm, init_whisper, sample_token
from repro.models.whisper import (whisper_decode_step, whisper_forward,
                                  whisper_prefill)

KEY = jax.random.PRNGKey(0)


def test_greedy_sampling_deterministic():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 1.0]])
    t = sample_token(logits, KEY, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(t), [1, 0])


def test_engine_generates_fixed_shape():
    cfg = get_smoke("gemma2_2b")
    params = init_lm(cfg, KEY)
    eng = ServeEngine(cfg, params, batch=2, max_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab,
                                                (2, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert eng.stats.decode_tokens == 2 * 5  # first token from prefill


def test_engine_eos_early_stop():
    cfg = get_smoke("h2o_danube_1_8b")
    params = init_lm(cfg, KEY)
    # greedy with eos = whatever token is argmax first -> stops immediately
    eng = ServeEngine(cfg, params, batch=2, max_len=64, eos=-2)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, max_new_tokens=8)
    assert out.shape[1] <= 8


def test_whisper_decode_matches_forward():
    """Teacher-forced whisper decode equals the full decoder forward."""
    cfg = get_smoke("whisper_small")
    params = init_whisper(cfg, KEY)
    B, S = 2, 12
    frames = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model))
    toks = np.asarray(jax.random.randint(KEY, (B, S), 0, cfg.vocab))
    full = whisper_forward(cfg, params, frames, jnp.asarray(toks))
    sp = 4
    lp, cache = whisper_prefill(cfg, params, frames,
                                jnp.asarray(toks[:, :sp]), max_len=32)
    errs = [np.abs(np.asarray(lp) - np.asarray(full[:, sp - 1])).max()]
    for t in range(sp, S):
        ld, cache = whisper_decode_step(cfg, params, cache,
                                        jnp.asarray(toks[:, t]),
                                        jnp.int32(t))
        errs.append(np.abs(np.asarray(ld) - np.asarray(full[:, t])).max())
    assert max(errs) < 0.25, f"whisper decode diverges: {max(errs)}"


def test_moe_expert_gather_matches_dense():
    """Decode fast path (gather top-k experts) == dense dispatch path."""
    from repro.models.layers import init_moe, moe_apply
    p = init_moe(KEY, 32, 64, n_experts=4, glu=True, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32))
    y_gather, _ = moe_apply(p, x, top_k=2, no_drop=True)   # T*k=2 <= E=4
    x8 = jnp.broadcast_to(x, (1, 8, 32))                   # T*k=16 > E
    y_dense, _ = moe_apply(p, x8, top_k=2, no_drop=True)
    np.testing.assert_allclose(np.asarray(y_gather[0, 0]),
                               np.asarray(y_dense[0, 0]), atol=1e-5,
                               rtol=1e-5)
