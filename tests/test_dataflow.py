"""Dataflow layer (repro.core.dataflow): the HLS-dialect analogue.

Invariants:
* window-buffer depths come straight from the stencil access offsets
  (``lo reach + region lead + 1``), per field;
* in-region stream-axis dependencies become ring buffers (negative
  offsets) or region splits (positive offsets / periodic temps), never
  recompute;
* legalisation is deterministic and order-preserving, and a plan's cached
  ``StreamSpec`` reproduces the same regions;
* 1-D programs have no stream schedule (nothing would stay vectorised).
"""

import pytest

from repro.apps import pw_advection, tracer_advection
from repro.core import lower_to_dataflow, plan_from_dict, plan_to_dict
from repro.core.dataflow import (Compute, Load, Store, Window,
                                 legalize_stream_groups, stream_halo)
from repro.core.frontend import ProgramBuilder
from repro.core.schedule import auto_plan

GRID = (8, 8, 16)


def chain_program(boundary="zero"):
    """in -> a (read back at -2) -> b (read ahead at +1) -> out."""
    b = ProgramBuilder("chain", ndim=3, boundary=boundary)
    u, = b.inputs("u")
    a = b.temp("a")
    c = b.temp("c")
    out = b.output("out")
    b.define(a, u[-2, 0, 0] + u[1, 0, 0])
    b.define(c, a[-2, 0, 0] + a[0, 0, 0])    # past planes: ring buffer
    b.define(out, c[1, 0, 0] + c[0, 0, 0])   # future plane: region split
    return b.build()


# ----------------------------------------------------------- buffer sizing

def test_window_depths_from_access_offsets():
    """pw_advection reads u/v/w at stream offsets in [-1, +1]: every window
    holds lo(1) + lead(1) + 1 = 3 planes — the paper's 3-plane shift
    register for a 3-point reach along the outer axis."""
    p = pw_advection()
    graph = lower_to_dataflow(p, auto_plan(p, GRID, schedule="stream"))
    assert len(graph.regions) == 1
    r = graph.regions[0]
    assert r.lead == 1
    assert r.depths == {"u": 3, "v": 3, "w": 3}
    assert r.rings == {}


def test_per_field_depths_differ_with_reach():
    """A field reaching further back needs a deeper buffer than one that
    only reads the current plane — depths are per field, not per region."""
    b = ProgramBuilder("mixed", ndim=2)
    u, v = b.inputs("u", "v")
    out = b.output("out")
    b.define(out, u[-3, 0] + u[1, 0] + v[0, 0])
    p = b.build()
    r = lower_to_dataflow(p, auto_plan(p, (16, 16), schedule="stream")
                          ).regions[0]
    assert r.lead == 1
    assert r.depths == {"u": 5, "v": 2}      # lo + lead + 1


def test_ring_buffer_depth_and_positive_offset_split():
    p = chain_program()
    plan = auto_plan(p, GRID, schedule="stream", strategy="fused")
    graph = lower_to_dataflow(p, plan)
    assert [r.ops for r in graph.regions] == [[0, 1], [2]]
    r0 = graph.regions[0]
    assert r0.rings == {"a": 3}              # read at -2: 1 + 2 planes
    # the split temp is materialised: region 0 stores c, region 1 loads it
    assert "c" in r0.halo.group_outputs
    assert graph.regions[1].halo.group_inputs == ["c"]


def test_periodic_temp_backreference_splits():
    """A periodic temp read at a negative stream offset cannot ride a ring
    (its wraparound planes are not resident yet) — the region splits and
    the temp wraps through HBM padding instead."""
    b = ProgramBuilder("ptemp", ndim=2, boundary="periodic")
    u, = b.inputs("u")
    a = b.temp("a")
    out = b.output("out")
    b.define(a, u[-1, 0] + u[1, 0])
    b.define(out, a[-1, 0] + a[0, 0])
    p = b.build()
    assert legalize_stream_groups(p, [[0, 1]]) == [[0], [1]]
    # the same dependency on a zero-boundary program stays fused (ring)
    pz = p.with_boundary("zero")
    assert legalize_stream_groups(pz, [[0, 1]]) == [[0, 1]]


# ------------------------------------------------------ stream-aware halos

def test_stream_halo_has_no_stream_margins():
    """Block-schedule margins extend producers along every axis; stream
    margins only widen the non-stream axes (rings replace recompute)."""
    b = ProgramBuilder("m", ndim=3)
    u, = b.inputs("u")
    a = b.temp("a")
    out = b.output("out")
    b.define(a, u[1, 1, 0] + u[-1, -1, 0])
    b.define(out, a[-1, 1, 0] + a[0, -1, 0])
    p = b.build()
    gh = stream_halo(p, [0, 1])
    m_a = gh.margins[0]
    assert m_a[0].tolist() == [0, 0]         # stream axis: ring, no margin
    assert m_a[1].tolist() == [1, 1]         # y: consumer offsets propagate
    # input halo along the stream axis is the raw reach, not margin-extended
    assert gh.input_halo[0].tolist() == [1, 1]
    assert gh.input_halo[1].tolist() == [2, 2]


# ----------------------------------------------------- graph structure

def test_graph_nodes_and_text():
    p = pw_advection()
    graph = lower_to_dataflow(p, auto_plan(p, GRID, schedule="stream"))
    nodes = graph.regions[0].nodes
    kinds = [type(n) for n in nodes]
    assert kinds.count(Load) == 3 and kinds.count(Window) == 3
    assert kinds.count(Compute) == 3 and kinds.count(Store) == 3
    txt = graph.to_text()
    assert "dataflow.window(%u) depth=3 reach=(-1,+1)" in txt
    assert "dataflow.store %su" in txt


def test_tracer_advection_legalises_into_streamable_regions():
    """The 24-op MUSCL chain splits exactly where divergences read fluxes
    at +1 along the stream axis; slope limiting (-1 back-references) stays
    fused via ring buffers."""
    p = tracer_advection()
    graph = lower_to_dataflow(p, auto_plan(p, (6, 8, 16), schedule="stream"))
    assert len(graph.regions) > 1            # positive offsets force splits
    for r in graph.regions:
        gh = stream_halo(p, r.ops)           # legal: no exception
        for i in r.ops:
            assert not gh.margins[i][0].any()
    assert sum(len(r.ops) for r in graph.regions) == len(p.ops)
    assert any(r.rings for r in graph.regions)


def test_cached_stream_spec_reproduces_regions():
    """A plan deserialised from the tuner cache (StreamSpec present) lowers
    to the same regions as the fresh legalisation."""
    p = tracer_advection()
    plan = auto_plan(p, (6, 8, 16), schedule="stream")
    fresh = lower_to_dataflow(p, plan)
    cached = plan_from_dict(plan_to_dict(plan))
    again = lower_to_dataflow(p, cached)
    assert [r.ops for r in again.regions] == [r.ops for r in fresh.regions]
    assert [r.depths for r in again.regions] == \
        [r.depths for r in fresh.regions]


def test_stream_rejects_1d_programs():
    b = ProgramBuilder("one", ndim=1)
    u, = b.inputs("u")
    out = b.output("out")
    b.define(out, u[-1] + u[1])
    p = b.build()
    with pytest.raises(ValueError, match="ndim >= 2"):
        auto_plan(p, (64,), schedule="stream")


def test_cached_spec_relegalised_when_boundary_changes():
    """Regression: a StreamSpec legalised under zero boundaries kept a
    periodic temp's negative stream offset fused (ring buffer) when the
    plan was reused on the ``boundary="periodic"`` variant — the ring's
    out-of-domain masking then silently corrupted the wraparound values.
    Cached regions must be re-validated against the program being lowered."""
    import numpy as np

    from repro.core import compile_program

    b = ProgramBuilder("regress", ndim=2)
    u, = b.inputs("u")
    a = b.temp("a")
    out = b.output("out")
    b.define(a, u[-1, 0] + u[1, 0])
    b.define(out, a[-1, 0] + a[0, 0])
    p = b.build()
    grid = (8, 16)
    plan = auto_plan(p, grid, schedule="stream", strategy="fused")
    assert [list(r) for r in plan.stream.regions] == [[0, 1]]  # ring-fused

    pp = p.with_boundary("periodic")
    graph = lower_to_dataflow(pp, plan)          # cached spec re-checked
    assert [r.ops for r in graph.regions] == [[0], [1]]

    rng = np.random.default_rng(3)
    fields = {"u": rng.normal(size=grid).astype(np.float32)}
    ref = compile_program(pp, grid, backend="jnp_fused")(fields, {}, {})
    got = compile_program(pp, grid, plan=plan)(fields, {}, {})
    np.testing.assert_allclose(np.asarray(got["out"]),
                               np.asarray(ref["out"]), atol=1e-6, rtol=1e-6)
