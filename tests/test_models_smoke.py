"""Per-architecture smoke tests (required deliverable f): reduced config of
the same family, one forward + one train step on CPU, output shapes + no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import (decode_step, init_cache, init_lm, init_whisper,
                          lm_forward, lm_loss, prefill, whisper_forward,
                          whisper_loss)
from repro.train.optimizer import OptConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _tokens(cfg, key=KEY, s=S):
    return jax.random.randint(key, (B, s), 0, cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    toks = _tokens(cfg)
    labels = jnp.roll(toks, -1, axis=1)
    if cfg.family == "encdec":
        params = init_whisper(cfg, KEY)
        frames = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model))
        logits = whisper_forward(cfg, params, frames, toks)
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())

        def loss_fn(p):
            return whisper_loss(cfg, p, frames, toks, labels)[0]
    else:
        params = init_lm(cfg, KEY)
        logits, _ = lm_forward(cfg, params, toks)
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())

        def loss_fn(p):
            return lm_loss(cfg, p, toks, labels)[0]

    # one optimizer step: loss finite, grads finite, params change
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves)
    opt = adamw_init(params)
    new_params, _, m = adamw_update(OptConfig(lr=1e-3), params, grads, opt)
    assert bool(jnp.isfinite(m["grad_norm"]))
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact_assignment(arch):
    """Pin every published full config against the assignment table."""
    cfg = get_config(arch)
    expect = {
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000, 8),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072, 8),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000, 0),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000, 0),
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000, 0),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144, 0),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536, 0),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001, 0),
        "whisper_small": (12, 768, 12, 12, 3072, 51865, 0),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304, 0),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab, cfg.n_experts)
    assert got == expect, f"{arch}: {got} != {expect}"


def test_param_counts_plausible():
    """Analytic N close to the architecture's nameplate size."""
    # (arch, expected params, tolerance)
    for arch, n_expect, tol in [
        ("mixtral_8x7b", 46.7e9, 0.10),
        ("h2o_danube_1_8b", 1.8e9, 0.10),
        ("nemotron_4_340b", 340e9, 0.10),
        ("gemma2_2b", 2.6e9, 0.25),       # nameplate excludes embeddings
        ("xlstm_350m", 350e6, 0.30),
        ("grok_1_314b", 314e9, 0.15),
    ]:
        n = get_config(arch).num_params()
        assert abs(n - n_expect) / n_expect < tol, f"{arch}: {n:.3e}"


@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "gemma2_2b", "gemma3_1b",
                                  "mixtral_8x7b", "hymba_1_5b", "xlstm_350m",
                                  "nemotron_4_340b", "chameleon_34b",
                                  "grok_1_314b"])
def test_decode_matches_forward(arch):
    """Teacher-forced prefill+decode equals the training forward pass."""
    cfg = dataclasses.replace(get_smoke(arch), capacity_factor=8.0)
    params = init_lm(cfg, KEY)
    toks = np.asarray(_tokens(cfg, s=24))
    full, _ = lm_forward(cfg, params, jnp.asarray(toks))
    sp = 8
    lp, cache = prefill(cfg, params, jnp.asarray(toks[:, :sp]), max_len=64)
    errs = [np.abs(np.asarray(lp) - np.asarray(full[:, sp - 1])).max()]
    for t in range(sp, 24):
        ld, cache = decode_step(cfg, params, cache,
                                jnp.asarray(toks[:, t]), jnp.int32(t))
        errs.append(np.abs(np.asarray(ld) - np.asarray(full[:, t])).max())
    assert max(errs) < 0.25, f"{arch}: decode diverges {max(errs)}"  # bf16


def test_ring_buffer_cache_bounded():
    """SWA decode state stays at window size regardless of position."""
    cfg = get_smoke("h2o_danube_1_8b")
    params = init_lm(cfg, KEY)
    cache = init_cache(cfg, B, max_len=64)
    assert cache[0]["k"].shape[1] == cfg.window  # ring length = window
    tok = jnp.zeros((B,), jnp.int32)
    # decode far past the window: no growth, still finite
    logits, cache = decode_step(cfg, params, cache, tok, jnp.int32(60))
    assert cache[0]["k"].shape[1] == cfg.window
    assert bool(jnp.isfinite(logits).all())
