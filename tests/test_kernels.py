"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import pw_advection, tracer_advection
from repro.core import compile_program
from repro.kernels.ops import sliding_window_attention, stencil_apply
from repro.kernels.ref import stencil_reference, swa_reference

from strategies import make_data


# ------------------------------------------------------------- stencil3d

@pytest.mark.parametrize("grid", [(8, 8, 64), (16, 4, 128), (5, 9, 130)])
@pytest.mark.parametrize("dtype,atol", [("float32", 1e-4), ("bfloat16", 0.2)])
def test_stencil3d_shape_dtype_sweep(grid, dtype, atol):
    p = pw_advection()
    fields, scalars, coeffs = make_data(p, grid, seed=5)
    ref = stencil_reference(p, fields, scalars, coeffs)
    ex = compile_program(p, grid, backend="pallas", dtype=dtype)
    got = ex(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(ref[k]), atol=atol, rtol=atol)


def test_stencil_apply_wrapper():
    p = tracer_advection()
    grid = (8, 8, 64)
    fields, scalars, coeffs = make_data(p, grid, seed=6)
    fields["e3t"] = np.abs(fields["e3t"]) + 1.0
    scalars["zeps"] = np.float32(1e-6)
    got = stencil_apply(p, grid, fields, scalars, coeffs)
    ref = stencil_reference(p, fields, scalars, coeffs)
    np.testing.assert_allclose(np.asarray(got["ta"]), np.asarray(ref["ta"]),
                               atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------------ swa

@pytest.mark.parametrize("S,w,Bq", [(256, 64, 128), (256, 128, 64),
                                    (512, 256, 128), (128, 32, 128)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_swa_kernel_sweep(S, w, Bq, dtype, tol):
    B, H, D = 2, 4, 64
    key = jax.random.PRNGKey(S + w)
    q, k, v = (jax.random.normal(kk, (B, S, H, D), dtype=dtype)
               for kk in jax.random.split(key, 3))
    got = sliding_window_attention(q, k, v, window=w, q_block=Bq)
    ref = swa_reference(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_swa_kernel_gqa():
    B, S, H, KV, D, w = 2, 256, 8, 2, 64, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    got = sliding_window_attention(q, k, v, window=w)
    kr = jnp.repeat(k, H // KV, axis=2)
    vr = jnp.repeat(v, H // KV, axis=2)
    ref = swa_reference(q, kr, vr, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_swa_matches_model_layer_path():
    """Kernel agrees with the jnp swa_attention used inside the models."""
    from repro.models.layers import AttnSpec, swa_attention
    B, S, H, D, w = 2, 256, 4, 64, 64
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D))
               for kk in jax.random.split(key, 3))
    spec = AttnSpec(n_heads=H, n_kv_heads=H, d_head=D, window=w, chunk=256)
    a = swa_attention(q, k, v, spec)
    b = sliding_window_attention(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
