"""Shared test data builders + a hypothesis random-program generator.

The random generator fuzzes the whole compiler: random expression trees over
random fields with offsets in [-2, 2], optional scalars/coeffs, and random
producer->consumer chains — the property is that every backend agrees with
the jnp_naive oracle.

``make_data`` has no hypothesis dependency; the ``programs``/``expr_trees``
strategies are only defined when the test extra is installed, so plain test
modules can import this file in a bare environment.
"""

from __future__ import annotations

import numpy as np

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core.frontend import ProgramBuilder
from repro.core.ir import (Access, BinOp, BinOpKind, CoeffRef, Const, Expr,
                           ScalarRef, Select, Cmp, CmpKind, UnOp, UnOpKind)

SAFE_BIN = [BinOpKind.ADD, BinOpKind.SUB, BinOpKind.MUL, BinOpKind.MIN,
            BinOpKind.MAX]
SAFE_UN = [UnOpKind.NEG, UnOpKind.ABS, UnOpKind.TANH, UnOpKind.SQUARE]


def make_data(p, grid, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    fields = {f: rng.normal(size=grid).astype(dtype) for f in p.input_fields()}
    scalars = {s: dtype(rng.normal()) for s in p.scalars}
    coeffs = {c: rng.normal(size=(grid[ax],)).astype(dtype)
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


if HAVE_HYPOTHESIS:
    @st.composite
    def expr_trees(draw, readable, scalars, coeffs, ndim, depth=3):
        """Random expression over readable field names."""
        if depth == 0 or draw(st.integers(0, 3)) == 0:
            choice = draw(st.integers(0, 3))
            if choice == 0 and scalars:
                return ScalarRef(draw(st.sampled_from(scalars)))
            if choice == 1 and coeffs:
                return CoeffRef(draw(st.sampled_from(coeffs)),
                                draw(st.integers(-1, 1)))
            if choice == 2:
                return Const(float(draw(st.integers(-3, 3))))
            off = tuple(draw(st.integers(-2, 2)) for _ in range(ndim))
            return Access(draw(st.sampled_from(readable)), off)
        kind = draw(st.integers(0, 2))
        if kind == 0:
            return BinOp(draw(st.sampled_from(SAFE_BIN)),
                         draw(expr_trees(readable, scalars, coeffs, ndim,
                                         depth - 1)),
                         draw(expr_trees(readable, scalars, coeffs, ndim,
                                         depth - 1)))
        if kind == 1:
            return UnOp(draw(st.sampled_from(SAFE_UN)),
                        draw(expr_trees(readable, scalars, coeffs, ndim,
                                        depth - 1)))
        return Select(
            Cmp(CmpKind.GT,
                draw(expr_trees(readable, scalars, coeffs, ndim, depth - 1)),
                Const(0.0)),
            draw(expr_trees(readable, scalars, coeffs, ndim, depth - 1)),
            draw(expr_trees(readable, scalars, coeffs, ndim, depth - 1)))

    @st.composite
    def programs(draw, ndim=None):
        """Random stencil programs with dependency chains."""
        if ndim is None:
            ndim = draw(st.integers(1, 3))
        n_in = draw(st.integers(1, 3))
        n_ops = draw(st.integers(1, 5))
        n_scalars = draw(st.integers(0, 2))
        n_coeffs = draw(st.integers(0, 1)) if ndim >= 1 else 0

        b = ProgramBuilder("fuzz", ndim=ndim)
        ins = [b.input(f"in{i}") for i in range(n_in)]
        scalars = [f"s{i}" for i in range(n_scalars)]
        for s in scalars:
            b.scalar(s)
        coeffs = []
        if n_coeffs:
            ax = draw(st.integers(0, ndim - 1))
            b.coeff("cf0", axis=ax)
            coeffs = ["cf0"]

        readable = [f"in{i}" for i in range(n_in)]
        outs = []
        for i in range(n_ops):
            # last op must be an output; earlier ones may be temps
            is_out = (i == n_ops - 1) or draw(st.booleans())
            name = f"o{i}"
            h = b.output(name) if is_out else b.temp(name)
            expr = draw(expr_trees(readable, scalars, coeffs, ndim,
                                   depth=draw(st.integers(1, 3))))
            b.define(h, expr)
            readable.append(name)
            outs.append(name)
        return b.build()
