"""End-to-end system behaviour: the paper's full pipeline, both domains.

Stencil side: frontend -> IR -> auto-plan -> Pallas dataflow kernels ->
time-stepped solve (PW advection, the paper's benchmark 1).
LM side: data pipeline -> training with checkpoints -> serving with
ring-buffer caches, all through the public APIs.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import pw_advection, tracer_advection
from repro.core import compile_program, run_time_loop
from repro.core.schedule import vmem_cost
from repro.configs import get_smoke
from repro.data import BatchSpec, SyntheticLM
from repro.models import ServeEngine
from repro.train import OptConfig, TrainConfig, Trainer


def _pw_data(grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: (rng.normal(size=grid) * 0.1).astype(np.float32)
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs


def test_pw_advection_time_loop_stable():
    """Several coupled explicit steps through the Pallas backend: finite,
    and identical to the jnp oracle stepped the same way."""
    grid = (24, 20, 64)
    p = pw_advection()
    fields, scalars, coeffs = _pw_data(grid)
    dt = 0.05

    def update(fl, out):
        return {"u": fl["u"] + dt * out["su"],
                "v": fl["v"] + dt * out["sv"],
                "w": fl["w"] + dt * out["sw"]}

    ex_p = compile_program(p, grid, backend="pallas")
    ex_r = compile_program(p, grid, backend="jnp_naive")
    fp = run_time_loop(ex_p, {k: jnp.asarray(v) for k, v in fields.items()},
                       scalars, coeffs, steps=4, update=update)
    fr = run_time_loop(ex_r, {k: jnp.asarray(v) for k, v in fields.items()},
                       scalars, coeffs, steps=4, update=update)
    for k in fp:
        assert bool(jnp.isfinite(fp[k]).all())
        np.testing.assert_allclose(np.asarray(fp[k]), np.asarray(fr[k]),
                                   atol=1e-4, rtol=1e-4)


def test_plan_respects_vmem_budget_on_both_apps():
    from repro import hw
    for prog in (pw_advection(), tracer_advection()):
        grid = (256, 256, 512)
        ex = compile_program(prog, grid, backend="jnp_fused")  # plan only
        assert vmem_cost(prog, ex.plan, grid) <= hw.VMEM_PLAN_BUDGET


def test_full_lm_system_train_then_serve(tmp_path):
    """Train a smoke model through the Trainer (with a checkpoint), then
    serve from the trained weights — the whole substrate in one path."""
    cfg = get_smoke("gemma3_1b")
    spec = BatchSpec(global_batch=4, seq_len=24, vocab=cfg.vocab)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=20),
                       ckpt_every=4, ckpt_dir=str(tmp_path / "ck"),
                       log_every=1000)
    tr = Trainer(cfg, tcfg, SyntheticLM(spec, seed=0))
    hist = tr.run(6)
    assert all(np.isfinite(h["loss"]) for h in hist)
    eng = ServeEngine(cfg, tr.state["params"], batch=2, max_len=64)
    out = eng.generate(np.zeros((2, 6), np.int32), max_new_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()
