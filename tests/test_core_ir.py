"""Unit tests: stencil IR, frontend, passes."""

import numpy as np
import pytest

from repro.apps import pw_advection, tracer_advection
from repro.core.frontend import ProgramBuilder
from repro.core.ir import Access, FieldRole
from repro.core.passes import (classify, cse_stats, field_halo, infer_halo,
                               stage_split)
from repro.core.schedule import auto_plan, vmem_cost


def test_builder_roundtrip():
    b = ProgramBuilder("p", ndim=2)
    x, y = b.inputs("x", "y")
    s = b.scalar("s")
    o = b.output("o")
    b.define(o, s * x[-1, 0] + y[0, 1] * 2.0 - x[0, 0])
    p = b.build()
    assert p.input_fields() == ["x", "y"]
    assert p.output_fields() == ["o"]
    assert p.scalars == ["s"]
    assert "stencil.apply" in p.to_text()
    assert p.flops_per_point() > 0


def test_builder_rejects_bad_programs():
    b = ProgramBuilder("p", ndim=2)
    x = b.input("x")
    o = b.output("o")
    with pytest.raises(ValueError):
        x[1]  # wrong rank
    with pytest.raises(ValueError):
        b.define(x, x[0, 0])  # writing an input
    b.define(o, x[0, 0])
    with pytest.raises(ValueError):
        b.define(o, x[0, 0])  # double definition
    b2 = ProgramBuilder("q", ndim=1)
    t = b2.temp("t")
    o2 = b2.output("o")
    b2.define(o2, t[1])  # reads t before produced
    with pytest.raises(ValueError):
        b2.build()


def test_classify_pw():
    p = pw_advection()
    c = classify(p)
    assert set(c.inputs) == {"u", "v", "w"}
    assert set(c.outputs) == {"su", "sv", "sw"}
    assert c.scalars == ["tcx", "tcy"]
    assert set(p.coeffs) == {"tzc1", "tzc2", "tzd1", "tzd2"}


def test_halo_simple():
    b = ProgramBuilder("p", ndim=2)
    x = b.input("x")
    o = b.output("o")
    b.define(o, x[-2, 0] + x[1, 3])
    p = b.build()
    gh = infer_halo(p, [0])
    assert gh.input_halo.tolist() == [[2, 1], [0, 3]]
    assert field_halo(p).tolist() == [[2, 1], [0, 3]]


def test_halo_dependency_margins():
    """Producer consumed at offset must be recomputed on extended margin."""
    b = ProgramBuilder("p", ndim=1)
    x = b.input("x")
    t = b.temp("t")
    o = b.output("o")
    b.define(t, x[-1] + x[1])
    b.define(o, t[-1] + t[1])
    p = b.build()
    gh = infer_halo(p, [0, 1])
    assert gh.margins[0].tolist() == [[1, 1]]   # t needed one beyond tile
    assert gh.margins[1].tolist() == [[0, 0]]
    assert gh.input_halo.tolist() == [[2, 2]]   # x window needs 2
    assert gh.internal == ["t"]
    assert gh.group_outputs == ["o"]


def test_halo_chain_depth():
    """Margins accumulate along chains (tracer-advection structure)."""
    b = ProgramBuilder("p", ndim=1)
    x = b.input("x")
    prev = x
    handles = [x]
    for i in range(4):
        t = b.temp(f"t{i}") if i < 3 else b.output("o")
        b.define(t, handles[-1][-1] + handles[-1][1])
        handles.append(t)
    p = b.build()
    gh = infer_halo(p, [0, 1, 2, 3])
    assert gh.margins[0].tolist() == [[3, 3]]
    assert gh.input_halo.tolist() == [[4, 4]]


def test_stage_split_strategies():
    p = tracer_advection()
    per_field = stage_split(p, "per_field")
    assert len(per_field) == len(p.ops) == 24
    fused = stage_split(p, "fused")
    assert len(fused) == 1
    auto = stage_split(p, "auto")
    assert 1 <= len(auto) <= 24


def test_cse_sees_sharing_in_tracer():
    stats = cse_stats(tracer_advection())
    assert stats["reused_evals_saved"] > 0


def test_auto_plan_fits_budget():
    p = pw_advection()
    grid = (256, 256, 1024)
    plan = auto_plan(p, grid)
    assert vmem_cost(p, plan, grid) <= 32 * 1024**2
    assert plan.block[-1] % 128 == 0 or plan.block[-1] == grid[-1]


def test_auto_plan_small_grid_clamps():
    p = pw_advection()
    plan = auto_plan(p, (8, 8, 32))
    assert all(b >= 1 for b in plan.block)


def test_stage_split_bad_strategy_names_valid_ones():
    with pytest.raises(ValueError) as exc:
        stage_split(pw_advection(), "wat")
    msg = str(exc.value)
    assert "'fused'" in msg and "'per_field'" in msg and "'auto'" in msg


def test_mesh_axes_normalised_to_program_ndim():
    """Regression: the default was a hard-coded 3-tuple, wrong for 2-D."""
    b = ProgramBuilder("p2", ndim=2)
    x, = b.inputs("x")
    o = b.output("o")
    b.define(o, x[-1, 0] + x[0, 1])
    p2 = b.build()
    assert auto_plan(p2, (32, 128)).mesh_axes == (None, None)
    assert auto_plan(pw_advection(), (8, 8, 32)).mesh_axes == (None,) * 3
    from repro.core.schedule import DataflowPlan
    plan = DataflowPlan(groups=[[0]], block=(32, 128))
    assert plan.mesh_axes is None
    assert plan.mesh_axes_for(2) == (None, None)
    assert DataflowPlan(groups=[[0]], block=(32, 128),
                        mesh_axes=("x",)).mesh_axes_for(2) == ("x", None)


def test_vmem_cost_accounts_for_fused_loop_carry():
    """Regression: a plan can fit the budget single-step yet claim more
    VMEM under steps=N, where windows are sliced from the align_hi-padded
    carry; the steps-aware cost must be >= the single-step cost."""
    p = pw_advection()
    grid = (8, 8, 130)      # 130 -> 2x128 lane tiles: align_hi = 126
    plan = auto_plan(p, grid, backend="pallas")
    single = vmem_cost(p, plan, grid)
    looped = vmem_cost(p, plan, grid, steps=3)
    assert looped > single
    # and on an exactly-aligned grid the two geometries coincide
    grid2 = (8, 8, 128)
    plan2 = auto_plan(p, grid2, backend="pallas")
    assert vmem_cost(p, plan2, grid2, steps=3) == vmem_cost(p, plan2, grid2)
