"""Fused on-device time loop: ``compile_program(..., steps=N, update=...)``.

Invariants:
* N fused on-device iterations match N host-side ``run_time_loop``
  iterations to 1e-5 on every backend (pallas interpret, jnp_fused,
  jnp_naive), including programs with scalars and per-level coefficients.
* The whole loop is one compiled program: the user's update rule is traced
  exactly once regardless of N, and repeated calls hit the jit cache.
* Both carry-write styles ("repad" rebuild and "inplace" scatter) agree.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import compile_program, plan_time_loop, run_time_loop
from repro.core.schedule import auto_plan

BACKENDS = ["jnp_naive", "jnp_fused", "pallas"]


def pw_data(grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.1)
              for f in ("u", "v", "w")}
    scalars = {"tcx": jnp.float32(0.05), "tcy": jnp.float32(0.05)}
    coeffs = {c: jnp.asarray(
        np.linspace(0.9, 1.1, grid[2]).astype(np.float32))
        for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs


def tracer_data(grid, seed=1):
    rng = np.random.default_rng(seed)
    fields = {
        "t": jnp.asarray(rng.normal(size=grid).astype(np.float32) + 15.0),
        "un": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "vn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "wn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.05),
        "e3t": jnp.asarray(
            np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0),
        "msk": jnp.asarray(
            (rng.uniform(size=grid) > 0.05).astype(np.float32)),
    }
    scalars = {"rdt": jnp.float32(0.05), "zeps": jnp.float32(1e-6)}
    coeffs = {"ztfreez": jnp.asarray(np.full(grid[2], -1.8, np.float32))}
    return fields, scalars, coeffs


def check_fused(p, grid, data, update, steps, backend, atol=1e-5,
                **compile_kw):
    fields, scalars, coeffs = data
    ex = compile_program(p, grid, backend=backend, **compile_kw)
    ref = run_time_loop(ex, dict(fields), scalars, coeffs, steps, update)
    exN = compile_program(p, grid, backend=backend, steps=steps,
                          update=update, **compile_kw)
    got = exN(fields, scalars, coeffs)
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(ref[k]), atol=atol, rtol=atol,
            err_msg=f"{p.name}/{k} backend={backend} steps={steps}")
    return exN


# ------------------------------------------------- parity (scalars + coeffs)

@pytest.mark.parametrize("backend", BACKENDS)
def test_pw_advection_fused_matches_host_loop(backend):
    grid = (8, 8, 128)
    check_fused(pw_advection(), grid, pw_data(grid),
                pw_advection_update(0.1), steps=4, backend=backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_tracer_advection_fused_matches_host_loop(backend):
    grid = (6, 8, 64)
    check_fused(tracer_advection(), grid, tracer_data(grid),
                tracer_advection_update(), steps=3, backend=backend)


@pytest.mark.parametrize("grid", [(5, 7, 130), (9, 6, 96)])
def test_fused_loop_odd_grids_alignment(grid):
    """Non-divisible grids: the carry keeps lane-alignment padding."""
    check_fused(pw_advection(), grid, pw_data(grid),
                pw_advection_update(0.1), steps=3, backend="pallas")


@pytest.mark.parametrize("strategy", ["fused", "per_field", "auto"])
def test_fused_loop_multi_group_strategies(strategy):
    """Cross-group temps re-materialise per step inside the loop."""
    grid = (6, 8, 64)
    check_fused(tracer_advection(), grid, tracer_data(grid),
                tracer_advection_update(), steps=2, backend="pallas",
                strategy=strategy)


@pytest.mark.parametrize("carry_write", ["repad", "inplace"])
def test_fused_loop_carry_write_styles(carry_write):
    grid = (8, 8, 128)
    check_fused(pw_advection(), grid, pw_data(grid),
                pw_advection_update(0.1), steps=3, backend="pallas",
                carry_write=carry_write)


def test_steps_one_equals_single_step_plus_update():
    grid = (8, 8, 64)
    p = pw_advection()
    fields, scalars, coeffs = pw_data(grid)
    update = pw_advection_update(0.1)
    out = compile_program(p, grid, backend="jnp_fused")(fields, scalars,
                                                        coeffs)
    want = update(fields, out)
    got = compile_program(p, grid, backend="jnp_fused", steps=1,
                          update=update)(fields, scalars, coeffs)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-6, rtol=1e-6)


# ------------------------------------------------------ single-dispatch jit

@pytest.mark.parametrize("backend", BACKENDS)
def test_update_traced_once_per_compile(backend):
    """The loop lowers into ONE jitted program: the update rule is traced
    exactly once for steps=5 (a host-driven loop traces/dispatches it per
    step), and a second executable call hits the jit cache (no retrace)."""
    grid = (6, 6, 64)
    p = pw_advection()
    fields, scalars, coeffs = pw_data(grid)
    inner = pw_advection_update(0.1)
    traces = [0]

    def update(fl, out):
        traces[0] += 1
        return inner(fl, out)

    ex = compile_program(p, grid, backend=backend, steps=5, update=update)
    ex(fields, scalars, coeffs)
    ex(fields, scalars, coeffs)
    assert traces[0] == 1


def test_partial_update_keeps_untouched_fields():
    """An update returning a subset of fields leaves the rest unchanged."""
    grid = (6, 6, 64)
    p = tracer_advection()
    fields, scalars, coeffs = tracer_data(grid)
    exN = compile_program(p, grid, backend="jnp_fused", steps=2,
                          update=lambda fl, out: {"t": out["ta"]})
    got = exN(fields, scalars, coeffs)
    for f in ("un", "vn", "wn", "e3t", "msk"):
        np.testing.assert_array_equal(np.asarray(got[f]),
                                      np.asarray(fields[f]))


# ------------------------------------------------------------ plan layer

def test_time_loop_spec_geometry():
    p = pw_advection()
    grid = (8, 8, 130)
    plan = auto_plan(p, grid, backend="pallas")
    spec = plan_time_loop(p, plan, grid, 7)
    assert spec.steps == 7
    assert spec.persistent == ["u", "v", "w"]
    assert set(spec.double_buffer) == {"u", "v", "w"}
    slots = [s for pair in spec.double_buffer.values() for s in pair]
    assert len(slots) == len(set(slots))  # disjoint front/back slots
    for f in spec.persistent:
        pad = spec.field_pad[f]
        assert pad.shape == (3, 2)
        assert (pad >= 0).all()
        # lane axis alignment: 130 -> 2x128 tiles pads 126 on the hi side
        assert pad[2, 1] >= 126
    # offsets place every group window inside the carry
    for offs in spec.group_offsets:
        for f, o in offs.items():
            assert all(v >= 0 for v in o)


def dead_op_program():
    """A live 1-wide stencil plus a DCE'd op reaching 4 cells up-axis-0."""
    from repro.core.frontend import ProgramBuilder
    b = ProgramBuilder("deadop", ndim=3)
    u, = b.inputs("u")
    dead = b.temp("dead")                     # produced, never consumed
    su = b.output("su")
    b.define(dead, u[4, 0, 0] * 2.0)
    b.define(su, u[-1, 0, 0] + u[1, 0, 0] - 2.0 * u[0, 0, 0])
    return b.build()


def test_dead_op_carry_padding_gated_on_backend():
    """Regression: the raw-access widening workaround is for the jnp
    lowerings (which evaluate every op, no DCE); the pallas backend only
    runs live fuse groups, so its carry must not be over-allocated for a
    dead op's reach."""
    p = dead_op_program()
    grid = (8, 8, 128)
    pallas_spec = plan_time_loop(p, auto_plan(p, grid, backend="pallas"),
                                 grid, 2)
    jnp_spec = plan_time_loop(p, auto_plan(p, grid, backend="jnp_fused"),
                              grid, 2)
    # live halo on axis 0 is 1; the dead op reads at +4
    assert pallas_spec.field_pad["u"][0, 1] == 1
    assert jnp_spec.field_pad["u"][0, 1] == 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_dead_op_fused_loop_parity(backend):
    """Both carry geometries stay numerically correct with a dead op."""
    grid = (8, 8, 64)
    p = dead_op_program()
    rng = np.random.default_rng(3)
    fields = {"u": jnp.asarray(rng.normal(size=grid).astype(np.float32))}
    check_fused(p, grid, (fields, {}, {}),
                lambda fl, out: {"u": fl["u"] + 0.1 * out["su"]},
                steps=3, backend=backend)


def test_steps_requires_update():
    p = pw_advection()
    with pytest.raises(ValueError, match="update"):
        compile_program(p, (8, 8, 64), backend="jnp_fused", steps=3)


def test_bad_carry_write_rejected():
    p = pw_advection()
    with pytest.raises(ValueError, match="carry_write"):
        compile_program(p, (8, 8, 64), backend="jnp_fused", steps=3,
                        update=pw_advection_update(), carry_write="wat")
