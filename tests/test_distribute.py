"""Distributed single-step executor: halo exchange vs single-device oracle.

Exercised through the unified ``compile_program(..., mesh=, mesh_axes=)``
entry point (the planner-driven sharded lowering); the deprecated
``make_sharded_executor`` wrapper is checked once for back-compat.

Runs in a subprocess so the 8-device XLA host-platform override never leaks
into other tests (which must see 1 device).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import warnings
import numpy as np, jax, jax.numpy as jnp
from repro.apps import pw_advection, tracer_advection
from repro.core import compile_program
from repro.core.frontend import ProgramBuilder
from repro.core.distribute import make_sharded_executor
from repro.dist.sharding import make_auto_mesh

rng = np.random.default_rng(7)

def data(p, grid):
    fields = {f: rng.normal(size=grid).astype(np.float32) for f in p.input_fields()}
    if "e3t" in fields: fields["e3t"] = np.abs(fields["e3t"]) + 1.0
    if "msk" in fields: fields["msk"] = (fields["msk"] > 0).astype(np.float32)
    scalars = {s: np.float32(0.1) for s in p.scalars}
    coeffs = {c: rng.normal(size=(grid[ax],)).astype(np.float32)
              for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs

def check(p, grid, mesh_shape, names, mesh_axes, backend="pallas"):
    mesh = make_auto_mesh(mesh_shape, names)
    fields, scalars, coeffs = data(p, grid)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars, coeffs)
    ex = compile_program(p, grid, backend=backend, mesh=mesh,
                         mesh_axes=mesh_axes)
    out = ex(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=f"{p.name}/{k} mesh={mesh_shape} "
                                           f"backend={backend}")

# 3-axis decomposition of both paper kernels
check(pw_advection(), (16, 12, 256), (2, 2, 2), ("X","Y","Z"), ("X","Y","Z"))
check(tracer_advection(), (16, 16, 128), (2, 2, 2), ("X","Y","Z"), ("X","Y","Z"))
# 1-axis and 2-axis layouts (unsharded trailing axes)
check(pw_advection(), (32, 8, 128), (8,), ("X",), ("X", None, None))
check(tracer_advection(), (8, 32, 128), (2, 4), ("X","Y"), ("X", "Y", None))
# jnp backends are sharded citizens too (satellite: backend forwarding)
check(pw_advection(), (16, 12, 128), (2, 2), ("X","Y"), ("X","Y",None),
      backend="jnp_fused")
check(tracer_advection(), (8, 16, 64), (2, 2), ("X","Y"), ("X","Y",None),
      backend="jnp_naive")
# periodic torus across shard boundaries
check(pw_advection(boundary="periodic"), (16, 12, 128), (2, 2, 2),
      ("X","Y","Z"), ("X","Y","Z"))
check(tracer_advection(boundary="periodic"), (8, 16, 64), (2, 4),
      ("X","Y"), ("X","Y",None))
# diagonal-offset corner correctness
b = ProgramBuilder("diag", ndim=2)
x = b.input("x"); o = b.output("o")
b.define(o, x[-1, -1] + x[1, 1] + x[-2, 2])
check(b.build(), (16, 32), (2, 4), ("X","Y"), ("X","Y"))
# same stencil on a torus (wraparound corners)
bp = ProgramBuilder("diagp", ndim=2, boundary="periodic")
xp = bp.input("x"); op = bp.output("o")
bp.define(op, xp[-1, -1] + xp[1, 1] + xp[-2, 2])
check(bp.build(), (16, 32), (2, 4), ("X","Y"), ("X","Y"))
# dependency chain across shard boundary (margin recompute in halo)
b2 = ProgramBuilder("chain", ndim=1)
x2 = b2.input("x"); t2 = b2.temp("t"); o2 = b2.output("o")
b2.define(t2, x2[-1] + x2[1])
b2.define(o2, t2[-1] * t2[1])
check(b2.build(), (64,), (8,), ("X",), ("X",))

# deprecated wrapper: warns, forwards backend, still correct
p = pw_advection()
grid = (16, 12, 128)
mesh = make_auto_mesh((2, 2), ("X", "Y"))
fields, scalars, coeffs = data(p, grid)
ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars, coeffs)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    legacy = make_sharded_executor(p, grid, mesh, ("X", "Y", None),
                                   backend="jnp_fused")
assert any(issubclass(x.category, DeprecationWarning) for x in w)
assert legacy.plan.backend == "jnp_fused"   # backend forwarded to the plan
assert legacy.local_grid == (8, 6, 128)
out = legacy(fields, scalars, coeffs)
for k in ref:
    np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                               atol=1e-4, rtol=1e-4)
print("DIST_OK")
"""


@pytest.mark.slow
def test_distributed_halo_exchange():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "DIST_OK" in r.stdout


DEPRECATION_SCRIPT = r"""
import warnings
import numpy as np, jax
from repro.apps import pw_advection
from repro.core import compile_program
from repro.core.distribute import make_sharded_executor
from repro.dist.sharding import make_auto_mesh

assert jax.device_count() == 2
rng = np.random.default_rng(11)
p = pw_advection()
grid = (8, 8, 128)
fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
          for f in ("u", "v", "w")}
scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
          for c in ("tzc1", "tzc2", "tzd1", "tzd2")}

for shape, axes in (((1, 1), ("X", "Y", None)), ((1, 2), ("X", "Y", None))):
    mesh = make_auto_mesh(shape, ("X", "Y"))
    ref = compile_program(p, grid, backend="jnp_fused", mesh=mesh,
                          mesh_axes=axes)(fields, scalars, coeffs)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = make_sharded_executor(p, grid, mesh, axes,
                                       backend="jnp_fused")
    assert any(issubclass(x.category, DeprecationWarning) for x in w), shape
    # legacy attribute surface still present
    assert legacy.local_grid == legacy.shard.local_grid
    assert legacy.mesh_axes == legacy.shard.mesh_axes
    out = legacy(fields, scalars, coeffs)
    for k in ref:
        # the wrapper forwards to compile_program with identical arguments,
        # so the compiled graphs are the same: results must BIT-match
        a, b = np.asarray(out[k]), np.asarray(ref[k])
        assert a.tobytes() == b.tobytes(), (shape, k,
                                            np.abs(a - b).max())
print("DEPRECATION_OK")
"""


@pytest.mark.slow
def test_make_sharded_executor_deprecation_bitmatch():
    """The deprecated wrapper warns and its results bit-match
    ``compile_program`` on a degenerate 1x1 and a real 1x2 mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", DEPRECATION_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "DEPRECATION_OK" in r.stdout
