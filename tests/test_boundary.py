"""Boundary-condition subsystem: per-field "zero" / "periodic" halos.

Invariants:
* periodic semantics = numpy wraparound (``np.roll``) on every backend;
* the full paper kernels agree across backends on a torus, single-step and
  fused-loop, including deep temp chains and per-level coefficients;
* the IR rejects incoherent mixes (a periodic field produced from
  zero-boundary inputs has no recomputable wraparound value);
* boundaries are part of a program's semantic fingerprint.
"""

import numpy as np
import pytest

from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import compile_program, program_fingerprint, run_time_loop
from repro.core.frontend import ProgramBuilder

BACKENDS = ["jnp_naive", "jnp_fused", "pallas"]


def lap2d(boundary):
    b = ProgramBuilder("lap", ndim=2, boundary=boundary)
    x = b.input("x")
    o = b.output("o")
    b.define(o, x[-1, 0] + x[1, 0] + x[0, -1] + x[0, 1] - 4.0 * x[0, 0])
    return b.build()


def pw_data(grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": np.float32(0.05), "tcy": np.float32(0.05)}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs


def tracer_data(grid, seed=1):
    rng = np.random.default_rng(seed)
    fields = {
        "t": rng.normal(size=grid).astype(np.float32) + 15.0,
        "un": rng.normal(size=grid).astype(np.float32) * 0.2,
        "vn": rng.normal(size=grid).astype(np.float32) * 0.2,
        "wn": rng.normal(size=grid).astype(np.float32) * 0.05,
        "e3t": np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0,
        "msk": (rng.uniform(size=grid) > 0.05).astype(np.float32),
    }
    scalars = {"rdt": np.float32(0.05), "zeps": np.float32(1e-6)}
    coeffs = {"ztfreez": np.full(grid[2], -1.8, np.float32)}
    return fields, scalars, coeffs


# ------------------------------------------------ wraparound ground truth

@pytest.mark.parametrize("backend", BACKENDS)
def test_periodic_matches_numpy_roll(backend):
    p = lap2d("periodic")
    rng = np.random.default_rng(3)
    x = rng.normal(size=(8, 128)).astype(np.float32)
    want = (np.roll(x, 1, 0) + np.roll(x, -1, 0)
            + np.roll(x, 1, 1) + np.roll(x, -1, 1) - 4 * x)
    out = compile_program(p, (8, 128), backend=backend)({"x": x})
    np.testing.assert_allclose(np.asarray(out["o"]), want,
                               atol=1e-6, rtol=1e-6)


def test_zero_boundary_unchanged_semantics():
    """The default boundary is still zero extension at the edges."""
    p = lap2d("zero")
    x = np.ones((6, 130), np.float32)
    out = np.asarray(compile_program(p, (6, 130), backend="jnp_naive")(
        {"x": x})["o"])
    assert out[3, 64] == 0.0          # interior of constant field
    assert out[0, 64] == -1.0         # one neighbour missing at the edge


# ------------------------------------------------ full kernels on a torus

@pytest.mark.parametrize("backend", ["jnp_fused", "pallas"])
def test_pw_advection_periodic_backend_parity(backend):
    grid = (6, 6, 64)
    p = pw_advection(boundary="periodic")
    fields, scalars, coeffs = pw_data(grid)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars,
                                                        coeffs)
    out = compile_program(p, grid, backend=backend)(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


@pytest.mark.parametrize("strategy", ["fused", "per_field", "auto"])
def test_tracer_periodic_multi_group_parity(strategy):
    """Margin recompute of periodic temps stays exact in fused groups
    (the mask gating: wrapped windows, no zero mask)."""
    grid = (6, 8, 64)
    p = tracer_advection(boundary="periodic")
    fields, scalars, coeffs = tracer_data(grid)
    ref = compile_program(p, grid, backend="jnp_naive")(fields, scalars,
                                                        coeffs)
    out = compile_program(p, grid, backend="pallas",
                          strategy=strategy)(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("carry_write", ["repad", "inplace"])
def test_fused_loop_periodic_matches_host_loop(backend, carry_write):
    """Periodic halo slabs are refreshed every step of the fused loop."""
    grid = (6, 6, 64)
    p = pw_advection(boundary="periodic")
    fields, scalars, coeffs = pw_data(grid)
    update = pw_advection_update(0.1)
    ex = compile_program(p, grid, backend=backend)
    want = run_time_loop(ex, dict(fields), scalars, coeffs, 3, update)
    got = compile_program(p, grid, backend=backend, steps=3, update=update,
                          carry_write=carry_write)(fields, scalars, coeffs)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


def test_fused_loop_tracer_periodic():
    grid = (6, 8, 64)
    p = tracer_advection(boundary="periodic")
    fields, scalars, coeffs = tracer_data(grid)
    update = tracer_advection_update()
    ex = compile_program(p, grid, backend="jnp_naive")
    want = run_time_loop(ex, dict(fields), scalars, coeffs, 2, update)
    got = compile_program(p, grid, backend="pallas", steps=2,
                          update=update)(fields, scalars, coeffs)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5, rtol=1e-5, err_msg=k)


# ------------------------------------------------ IR-level rules

def test_with_boundary_override():
    p = pw_advection()
    assert not p.is_torus()
    pt = p.with_boundary("periodic")
    assert pt.is_torus()
    assert not p.is_torus()                      # original untouched
    assert set(pt.boundaries().values()) == {"periodic"}
    # compile_program(boundary=...) is the same override inline
    grid = (6, 6, 64)
    fields, scalars, coeffs = pw_data(grid)
    a = compile_program(pt, grid, backend="jnp_fused")(fields, scalars,
                                                       coeffs)
    b = compile_program(p, grid, backend="jnp_fused",
                        boundary="periodic")(fields, scalars, coeffs)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_unknown_boundary_rejected():
    with pytest.raises(ValueError, match="boundary"):
        lap2d("reflect")


def test_with_boundary_unknown_field_rejected():
    """A typo in a per-field mapping must not silently compile the wrong
    boundary condition."""
    with pytest.raises(ValueError, match="unknown field"):
        pw_advection().with_boundary({"uu": "periodic"})


def test_periodic_field_from_zero_inputs_rejected():
    b = ProgramBuilder("bad", ndim=1)
    x = b.input("x", boundary="zero")
    t = b.temp("t", boundary="periodic")
    o = b.output("o", boundary="zero")
    b.define(t, x[-1] + x[1])
    b.define(o, t[-1] * t[1])
    with pytest.raises(ValueError, match="periodic"):
        b.build()


def test_periodic_coeff_requires_torus():
    b = ProgramBuilder("badc", ndim=1)
    x = b.input("x", boundary="periodic")
    o = b.output("o", boundary="periodic")
    b.input("y", boundary="zero")   # breaks the torus
    c = b.coeff("c", axis=0)
    b.define(o, x[1] * c[0])
    with pytest.raises(ValueError, match="torus"):
        b.build()


def test_mixed_boundaries_allowed_when_coherent():
    """A zero-boundary output may read periodic inputs: the boundary is a
    property of the field being *read*."""
    b = ProgramBuilder("mix", ndim=1)
    x = b.input("x", boundary="periodic")
    o = b.output("o", boundary="zero")
    b.define(o, x[-1] + x[1])
    p = b.build()
    v = np.arange(8, dtype=np.float32)
    want = np.roll(v, 1) + np.roll(v, -1)
    for backend in BACKENDS:
        out = compile_program(p, (8,), backend=backend)({"x": v})
        np.testing.assert_allclose(np.asarray(out["o"]), want, atol=1e-6)


def test_fingerprint_encodes_boundary():
    p = pw_advection()
    assert program_fingerprint(p) != program_fingerprint(
        p.with_boundary("periodic"))
