"""Unit tests for the roofline analysis machinery (deliverable g)."""

import numpy as np
import pytest

from repro.analysis.roofline import (analytic_flops, analytic_traffic,
                                     parse_collectives, roofline_report,
                                     RooflineTerms)
from repro.configs import SHAPES, get_config

HLO = """
HloModule jit_step

%region_0.1 (arg.1: f32[128,256]) -> f32[128,256] {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %r = f32[128,256]{1,0} add(%ar, %ar)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %ag = bf16[64,512]{1,0} all-gather(%p1), replica_groups=[2,128]<=[256], dimensions={0}
  %w = f32[128,256]{1,0} while(%init), condition=%region_1.2, body=%region_0.1
  %cp = f32[32]{0} collective-permute(%y), source_target_pairs={{0,1},{1,2}}
  ROOT %out = f32[128,256]{1,0} add(%w, %w)
}
"""


def test_parse_collectives_shapes_and_groups():
    coll = parse_collectives(HLO)
    ops = {c["op"]: c for c in coll}
    assert set(ops) == {"all-reduce", "all-gather", "collective-permute"}
    ar = ops["all-reduce"]
    assert ar["bytes"] == 128 * 256 * 4
    assert ar["group"] == 16
    assert ar["wire"] == 2 * ar["bytes"] * 15 // 16
    assert ar["in_loop"]        # region_0.1 is the while body
    ag = ops["all-gather"]
    assert ag["bytes"] == 64 * 512 * 2
    assert ag["group"] == 128
    assert not ag["in_loop"]
    assert ops["collective-permute"]["wire"] == 32 * 4


def test_loop_correction_applies_only_inside_while():
    rep = roofline_report(chips=256, cost={"flops": 1e9,
                                           "bytes accessed": 1e9},
                          hlo_text=HLO, scan_correction=10.0)
    coll = parse_collectives(HLO)
    base = sum(c["wire"] for c in coll)
    loop = sum(c["wire"] for c in coll if c["in_loop"])
    assert rep["wire_per_dev_loop_corrected"] == pytest.approx(
        base - loop + 10.0 * loop)


def test_dominant_term():
    t = RooflineTerms(compute_s=1.0, memory_s=0.5, collective_s=2.0)
    assert t.dominant == "collective"


def test_analytic_flops_moe_counts_active_only():
    mix = get_config("mixtral_8x7b")
    dense_equiv = mix.num_params()
    active = mix.num_active_params()
    assert active < 0.4 * dense_equiv          # top-2 of 8 experts
    af = analytic_flops(mix, SHAPES["train_4k"])
    tokens = 4096 * 256
    assert af["model_flops"] >= 6.0 * active * tokens
    assert af["model_flops"] < 6.5 * active * tokens + 1e18


def test_analytic_flops_decode_linear_in_batch():
    cfg = get_config("h2o_danube_1_8b")
    a = analytic_flops(cfg, SHAPES["decode_32k"])
    # decode flops ~ 2*N*B (+ window attention); far below a train step
    b = analytic_flops(cfg, SHAPES["train_4k"])
    assert a["total"] < b["total"] / 100


def test_analytic_traffic_decode_memory_floor():
    """Decode HBM floor >= one pass over the TP-sharded active params."""
    cfg = get_config("h2o_danube_1_8b")
    tr = analytic_traffic(cfg, SHAPES["decode_32k"], chips=256, tp=16,
                          fsdp=1, dp_total=16)
    assert tr["bytes_per_dev"] >= 2 * cfg.num_active_params() / 16


def test_traffic_train_fsdp_wire_scales_with_params():
    small = get_config("h2o_danube_1_8b")
    big = get_config("nemotron_4_340b")
    ws = analytic_traffic(small, SHAPES["train_4k"], chips=256, tp=16,
                          fsdp=16, dp_total=16)["wire_per_dev"]
    wb = analytic_traffic(big, SHAPES["train_4k"], chips=256, tp=16,
                          fsdp=16, dp_total=16)["wire_per_dev"]
    assert wb > ws


# ----------------------------------------------------------------------
# Stencil plan model (repro.analysis.stencil_roofline): reuse-aware bytes
# ----------------------------------------------------------------------

def test_stream_schedule_models_fewer_bytes_than_block():
    """The stream schedule charges each input cell once per sweep; the
    block schedule re-reads window overlaps every tile.  On the paper's
    advection kernel with a deliberately small block, the modeled
    bytes/point must separate — and the stream number must sit at the
    read-once floor (inputs + outputs, plus only the halo-ring fraction)."""
    import dataclasses

    from repro.analysis.stencil_roofline import (model_plan,
                                                 plan_bytes_per_point)
    from repro.apps import pw_advection
    from repro.core.schedule import auto_plan

    p = pw_advection()
    grid = (32, 32, 128)
    block = auto_plan(p, grid)
    small = dataclasses.replace(block, block=(4, 4, 128),
                                groups=[list(g) for g in block.groups])
    stream = auto_plan(p, grid, schedule="stream")

    b_small = plan_bytes_per_point(p, small, grid)
    b_stream = plan_bytes_per_point(p, stream, grid)
    assert b_stream < b_small

    # read-once floor: 3 inputs fetched once + 3 outputs written once,
    # times 4 bytes, inflated only by the padded halo ring (< 25% here)
    floor = (3 + 3) * 4
    assert floor <= b_stream < floor * 1.25
    # the 4x4 block re-reads its 6x6 overlap ring: strictly above the floor
    assert b_small > floor * 1.5

    # and the time model ranks accordingly for this memory-bound stencil
    assert model_plan(p, stream, grid) < model_plan(p, small, grid)


def test_model_plan_block_schedule_unchanged_for_jnp_backends():
    """Non-pallas candidates still collapse to the backend-level model —
    the schedule axis is a pallas-only dimension."""
    import dataclasses

    from repro.analysis.stencil_roofline import (model_program,
                                                 plan_bytes_per_point)
    from repro.apps import pw_advection
    from repro.core.schedule import auto_plan

    p = pw_advection()
    grid = (16, 16, 128)
    plan = dataclasses.replace(auto_plan(p, grid, backend="jnp_naive"),
                               backend="jnp_naive")
    assert plan_bytes_per_point(p, plan, grid) == \
        model_program(p).bytes_per_point["jnp_naive"]
