"""Auto-tuner (repro.core.tune): measured plan search + persistent cache.

Invariants:
* The search is deterministic in its measurements: an injected fake timer
  returning the same times yields the same winning plan.
* ``compile_program(..., strategy="tuned")`` is a pure cache hit after the
  first tune — zero timed runs, same plan.
* The cache is invalidated by program fingerprint and grid changes.
* The tuned plan is never slower than the ``auto_plan`` baseline on the
  tuner's own measurements (the baseline is always a candidate).
"""

import dataclasses
import json

import pytest

from repro.apps import pw_advection, pw_advection_update
from repro.core import (PlanCache, TuneConfig, compile_program,
                        get_tuned_plan, plan_from_dict, plan_to_dict,
                        program_fingerprint, tune_plan)
from repro.core.frontend import ProgramBuilder
from repro.core.schedule import auto_plan
from repro.core.tune import CACHE_SCHEMA_VERSION, cache_key

GRID = (8, 8, 16)


def make_fake_timer():
    """Deterministic fake: time depends only on the call index, and the
    candidate order is deterministic, so measurements are reproducible.
    Never calls ``fn`` — a counted call *is* a timed run."""
    calls = {"n": 0}

    def timer(fn):
        i = calls["n"]
        calls["n"] += 1
        return 0.001 * ((i * 7) % 13 + 1)

    return timer, calls


def small_program():
    b = ProgramBuilder("tune_small", ndim=3)
    u, = b.inputs("u")
    su = b.output("su")
    b.define(su, u[-1, 0, 0] + u[1, 0, 0] - 2.0 * u[0, 0, 0])
    return b.build()


def small_update(fields, out):
    return {"u": fields["u"] + 0.1 * out["su"]}


# ----------------------------------------------------------- determinism

@pytest.mark.parametrize("backend", ["jnp_fused", "pallas"])
def test_tuner_determinism_with_fake_timer(backend):
    """Same measurements => same winning plan (and same carry_write)."""
    results = []
    for _ in range(2):
        timer, _calls = make_fake_timer()
        cfg = TuneConfig(steps=2, max_measured=4, timer=timer)
        res = tune_plan(pw_advection(), GRID, backend=backend,
                        update=pw_advection_update(0.1), config=cfg,
                        cache=PlanCache(path=None))
        results.append(res)
    a, b = results
    assert plan_to_dict(a.plan) == plan_to_dict(b.plan)
    assert a.carry_write == b.carry_write
    assert a.record["label"] == b.record["label"]


# ------------------------------------------------------------ cache hits

def test_second_tuned_compile_is_pure_cache_hit(tmp_path):
    """Acceptance: the second ``strategy="tuned"`` compile performs zero
    timed runs and reuses the stored plan — across PlanCache instances
    (i.e. through the JSON file, not just process memory).  The proof is
    the observability counters: the cache counts its own hit, and the
    process-wide ``tune.timed_runs`` counter (incremented on *every* timer
    invocation, fake or real) does not move."""
    from repro.obs import global_metrics

    p = pw_advection()
    path = str(tmp_path / "plans.json")
    update = pw_advection_update(0.1)
    timed = global_metrics().counter("tune.timed_runs")

    timer1, calls1 = make_fake_timer()
    cache1 = PlanCache(path=path)
    t0 = timed.value
    ex1 = compile_program(p, GRID, backend="jnp_fused", strategy="tuned",
                          steps=2, update=update,
                          tune_config=TuneConfig(steps=2, max_measured=3,
                                                 timer=timer1),
                          plan_cache=cache1)
    assert calls1["n"] > 0          # the first compile really tuned
    assert timed.value == t0 + calls1["n"]   # every timing was counted
    assert cache1.misses >= 1 and cache1.hits == 0

    timer2, calls2 = make_fake_timer()
    cache2 = PlanCache(path=path)
    t1 = timed.value
    ex2 = compile_program(p, GRID, backend="jnp_fused", strategy="tuned",
                          steps=2, update=update,
                          tune_config=TuneConfig(steps=2, max_measured=3,
                                                 timer=timer2),
                          plan_cache=cache2)
    assert timed.value == t1        # pure cache hit: zero timed runs
    assert calls2["n"] == 0
    assert cache2.hits == 1 and cache2.misses == 0
    assert plan_to_dict(ex1.plan) == plan_to_dict(ex2.plan)
    assert ex1.time_spec.carry_write == ex2.time_spec.carry_write


def test_cache_file_format_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    timer, _ = make_fake_timer()
    res = tune_plan(small_program(), GRID, backend="jnp_fused",
                    update=small_update,
                    config=TuneConfig(steps=2, timer=timer),
                    cache=PlanCache(path=path))
    doc = json.load(open(path))
    assert doc["version"] == CACHE_SCHEMA_VERSION
    rec = doc["entries"][res.key]
    assert plan_to_dict(plan_from_dict(rec["plan"])) == rec["plan"]
    assert rec["fingerprint"] == program_fingerprint(small_program())
    assert rec["measured"] >= 1 and rec["candidates"] >= rec["measured"]


# ------------------------------------------------------ cache invalidation

def test_cache_invalidated_by_program_fingerprint(tmp_path):
    """A semantically different program misses the cache and re-tunes."""
    path = str(tmp_path / "plans.json")
    timer, calls = make_fake_timer()
    cfg = TuneConfig(steps=2, timer=timer)
    get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                   update=small_update, config=cfg, cache=PlanCache(path=path))
    n_first = calls["n"]
    assert n_first > 0

    b = ProgramBuilder("tune_small", ndim=3)   # same name, different stencil
    u, = b.inputs("u")
    su = b.output("su")
    b.define(su, u[0, -1, 0] + u[0, 1, 0] - 2.0 * u[0, 0, 0])
    other = b.build()
    assert program_fingerprint(other) != program_fingerprint(small_program())

    res = get_tuned_plan(other, GRID, backend="jnp_fused",
                         update=small_update, config=cfg,
                         cache=PlanCache(path=path))
    assert not res.cache_hit
    assert calls["n"] > n_first     # it measured again

    # while the original program still hits
    res2 = get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                          update=small_update, config=cfg,
                          cache=PlanCache(path=path))
    assert res2.cache_hit


def test_cache_invalidated_by_grid_change(tmp_path):
    path = str(tmp_path / "plans.json")
    timer, calls = make_fake_timer()
    cfg = TuneConfig(steps=2, timer=timer)
    cache = PlanCache(path=path)
    p = small_program()
    get_tuned_plan(p, GRID, backend="jnp_fused", update=small_update,
                   config=cfg, cache=cache)
    n_first = calls["n"]
    res = get_tuned_plan(p, (16, 8, 16), backend="jnp_fused",
                         update=small_update, config=cfg, cache=cache)
    assert not res.cache_hit and calls["n"] > n_first
    assert cache_key(p, GRID, "jnp_fused", True) != \
        cache_key(p, (16, 8, 16), "jnp_fused", True)


def test_cache_keyed_by_backend_dtype_and_mode():
    p = small_program()
    assert cache_key(p, GRID, "pallas", True) != \
        cache_key(p, GRID, "jnp_fused", True)
    assert cache_key(p, GRID, "pallas", True) != \
        cache_key(p, GRID, "pallas", False)
    # a float32 winner must not serve a bfloat16 compile, nor a single-step
    # winner a fused steps=N compile (different pruning + ranking)
    assert cache_key(p, GRID, "pallas", True, "float32") != \
        cache_key(p, GRID, "pallas", True, "bfloat16")
    assert cache_key(p, GRID, "pallas", True, mode="loop") != \
        cache_key(p, GRID, "pallas", True, mode="single")


# ------------------------------------------- measured quality guarantee

@pytest.mark.parametrize("backend", ["jnp_fused", "pallas"])
def test_tuned_never_slower_than_auto_plan_on_measurements(backend):
    """The auto_plan seed is always measured, so argmin <= baseline."""
    cfg = TuneConfig(steps=2, repeats=1, max_measured=3)
    res = tune_plan(pw_advection(), GRID, backend=backend,
                    update=pw_advection_update(0.1), config=cfg,
                    cache=PlanCache(path=None))
    base = res.baseline
    assert base is not None and base.us_fused is not None
    assert res.record["us_fused"] <= base.us_fused


def test_tuned_plan_compiles_and_matches_auto_plan_results():
    """The tuned executable computes the same answer as the heuristic one."""
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    p = small_program()
    fields = {"u": jnp.asarray(rng.normal(size=GRID).astype(np.float32))}
    timer, _ = make_fake_timer()
    ex_t = compile_program(p, GRID, backend="pallas", strategy="tuned",
                           tune_config=TuneConfig(steps=2, timer=timer),
                           plan_cache=PlanCache(path=None))
    ex_a = compile_program(p, GRID, backend="pallas")
    got = ex_t(fields, {}, {})
    want = ex_a(fields, {}, {})
    np.testing.assert_allclose(np.asarray(got["su"]),
                               np.asarray(want["su"]), atol=1e-6)


def test_tune_without_update_measures_single_step_only():
    timer, calls = make_fake_timer()
    res = tune_plan(small_program(), GRID, backend="jnp_fused",
                    config=TuneConfig(steps=2, timer=timer),
                    cache=PlanCache(path=None))
    assert res.record["us_fused"] is None
    assert res.record["us_single"] is not None
    assert calls["n"] == res.record["measured"]  # one timing per candidate


def test_candidate_blocks_lane_quantised():
    """Every measured pallas candidate keeps a lane-quantised last axis."""
    timer, _ = make_fake_timer()
    grid = (8, 8, 256)
    res = tune_plan(pw_advection(), grid, backend="pallas",
                    update=pw_advection_update(0.1),
                    config=TuneConfig(steps=2, max_measured=6, timer=timer),
                    cache=PlanCache(path=None))
    for c in res.measured:
        last = c.plan.block[-1]
        assert last == grid[-1] or last % 128 == 0


def test_force_retune_bypasses_cache(tmp_path):
    """The key encodes the problem, not the search effort; force_retune is
    the escape hatch for re-searching with different knobs."""
    path = str(tmp_path / "plans.json")
    timer, calls = make_fake_timer()
    cfg = TuneConfig(steps=2, timer=timer)
    get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                   update=small_update, config=cfg, cache=PlanCache(path=path))
    n_first = calls["n"]
    res = get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                         update=small_update,
                         config=dataclasses.replace(cfg, force_retune=True),
                         cache=PlanCache(path=path))
    assert not res.cache_hit and calls["n"] > n_first


def test_corrupt_cache_file_is_ignored(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text("{not json")
    timer, calls = make_fake_timer()
    res = get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                         update=small_update,
                         config=TuneConfig(steps=2, timer=timer),
                         cache=PlanCache(path=str(path)))
    assert not res.cache_hit and calls["n"] > 0
    assert json.load(open(path))["entries"]    # rewritten with the record


# --------------------------------------------- mesh topology + boundaries

def test_cache_keyed_by_mesh_topology_and_boundary():
    """The key separates mesh topologies (2x2x2 vs 4x2 vs local) and
    boundary conditions — a plan tuned for one must not serve another.
    Uses lightweight mesh stand-ins: the key only reads ``.shape``."""
    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    p = pw_advection()
    k_local = cache_key(p, GRID, "pallas", True)
    k_222 = cache_key(p, GRID, "pallas", True,
                      mesh=FakeMesh({"X": 2, "Y": 2, "Z": 2}),
                      mesh_axes=("X", "Y", "Z"))
    k_42 = cache_key(p, GRID, "pallas", True,
                     mesh=FakeMesh({"X": 4, "Y": 2}),
                     mesh_axes=("X", "Y", None))
    k_24 = cache_key(p, GRID, "pallas", True,
                     mesh=FakeMesh({"X": 2, "Y": 4}),
                     mesh_axes=("X", "Y", None))
    k_periodic = cache_key(p.with_boundary("periodic"), GRID, "pallas", True)
    assert len({k_local, k_222, k_42, k_24, k_periodic}) == 5


def test_tuned_plan_boundary_in_fingerprint():
    """Same program, different boundary => different tuner cache entry."""
    p = pw_advection()
    assert program_fingerprint(p) != \
        program_fingerprint(p.with_boundary("periodic"))


# --------------------------------------------------- plan copy-on-write

def test_compile_program_does_not_mutate_shared_plan():
    """Regression: ``compile_program`` used to retarget ``plan.backend`` in
    place; a plan served twice from the PlanCache (or held by the caller)
    would be silently corrupted by a second compile."""
    p = pw_advection()
    plan = auto_plan(p, GRID, backend="pallas")
    groups_before = [list(g) for g in plan.groups]
    ex = compile_program(p, GRID, backend="jnp_fused", plan=plan)
    assert plan.backend == "pallas"              # untouched
    assert ex.plan.backend == "jnp_fused"        # compiled copy retargeted
    assert plan.groups == groups_before
    ex.plan.groups[0].append(99)                 # and the copy is deep
    assert plan.groups == groups_before


# --------------------------------------------- cache schema evolution (v2)

def test_stale_cache_version_is_a_miss_and_rewritten(tmp_path):
    """A cache file written by another schema version never serves entries:
    the lookup misses (forcing a re-tune) and the next store rewrites the
    file at the current version — old records can't poison new fields."""
    path = str(tmp_path / "plans.json")
    timer, calls = make_fake_timer()
    cfg = TuneConfig(steps=2, timer=timer)
    res = tune_plan(small_program(), GRID, backend="jnp_fused",
                    update=small_update, config=cfg, cache=PlanCache(path=path))
    doc = json.load(open(path))
    assert doc["version"] == CACHE_SCHEMA_VERSION

    # forge a pre-schedule-era cache: same entries, version 1
    stale = {"version": 1, "entries": doc["entries"]}
    json.dump(stale, open(path, "w"))
    fresh = PlanCache(path=path)            # no in-memory copy
    assert fresh.lookup(res.key) is None    # stale version = miss

    calls["n"] = 0
    res2 = get_tuned_plan(small_program(), GRID, backend="jnp_fused",
                          update=small_update, config=cfg, cache=fresh)
    assert not res2.cache_hit and calls["n"] > 0    # re-tuned
    doc2 = json.load(open(path))
    assert doc2["version"] == CACHE_SCHEMA_VERSION  # rewritten current
    assert fresh.lookup(res2.key) is not None


def test_plan_from_dict_tolerates_schema_drift():
    """Unknown keys are ignored, keys a past version never wrote default."""
    plan = auto_plan(small_program(), GRID, backend="pallas")
    d = plan_to_dict(plan)

    # a future version's extra keys must not crash this one
    future = dict(d, schema=99, exotic_knob={"nested": [1, 2]})
    assert plan_to_dict(plan_from_dict(future)) == d

    # a pre-v2 record (no schema/schedule/stream) defaults to a block plan
    legacy = {k: v for k, v in d.items()
              if k not in ("schema", "schedule", "stream")}
    r = plan_from_dict(legacy)
    assert r.schedule == "block" and r.stream is None
    assert r.groups == plan.groups and r.block == plan.block

    # minimal ancient record: only the two originally-required keys
    r0 = plan_from_dict({"groups": [[0]], "block": [8, 8, 16]})
    assert r0.dtype == "float32" and r0.halo_every == 1


def test_plan_cache_roundtrips_stream_spec(tmp_path):
    """A stream-scheduled winner survives the JSON cache bit-for-bit:
    schedule, legalised regions, window depths, rings, leads."""
    p = pw_advection()
    plan = auto_plan(p, GRID, schedule="stream")
    assert plan.stream is not None and plan.stream.depths
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    cache.store("k", {"plan": plan_to_dict(plan), "carry_write": "repad"})
    rec = PlanCache(path=path).lookup("k")
    got = plan_from_dict(rec["plan"])
    assert got.schedule == "stream"
    assert got.stream == plan.stream
    assert plan_to_dict(got) == plan_to_dict(plan)


def test_tuner_enumerates_stream_and_block_schedules():
    """``strategy="tuned"`` searches both schedule values: the candidate
    set contains shift-register stream plans next to block plans, and the
    winner's schedule round-trips through the record."""
    from repro.core.tune import _candidates
    cfg = TuneConfig(steps=2, timer=lambda fn: 1.0)
    cands = _candidates(pw_advection(), GRID, "pallas", True, "float32",
                        cfg, with_loop=True)
    schedules = {c.plan.schedule for c in cands}
    assert schedules == {"block", "stream"}
    stream_cands = [c for c in cands if c.plan.schedule == "stream"]
    assert all(c.plan.stream is not None for c in stream_cands)
    # ...and the jnp backends never see stream candidates
    jcands = _candidates(pw_advection(), GRID, "jnp_fused", True, "float32",
                         cfg, with_loop=True)
    assert {c.plan.schedule for c in jcands} == {"block"}


def test_plan_cache_concurrent_writers_merge(tmp_path):
    """N threads storing distinct keys into one cache file must all
    survive: the rewrite is merge-on-write over a fresh re-read with a
    unique temp path per writer, so no store clobbers another's entries
    and no reader ever sees a torn file."""
    import threading

    path = str(tmp_path / "plans.json")
    n_threads, per_thread = 8, 10
    plan = auto_plan(pw_advection(), (8, 8, 16), backend="jnp_fused")
    rec = {"plan": plan_to_dict(plan), "carry_write": "repad"}
    caches = [PlanCache(path) for _ in range(n_threads)]
    start = threading.Barrier(n_threads)
    errs = []

    def writer(i):
        try:
            start.wait()
            for j in range(per_thread):
                caches[i].store(f"w{i}/k{j}", dict(rec, label=f"{i}/{j}"))
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == CACHE_SCHEMA_VERSION
    keys = {f"w{i}/k{j}" for i in range(n_threads)
            for j in range(per_thread)}
    assert keys <= set(doc["entries"])
    # and a fresh cache object reads every entry back
    fresh = PlanCache(path)
    for k in keys:
        assert fresh.lookup(k)["carry_write"] == "repad"


def test_plan_cache_shared_object_threadsafe(tmp_path):
    """One PlanCache instance shared by many threads (the serving engine's
    shape): stores and lookups interleave without losing entries."""
    import threading

    path = str(tmp_path / "plans.json")
    cache = PlanCache(path)
    plan = auto_plan(pw_advection(), (8, 8, 16), backend="jnp_fused")
    rec = {"plan": plan_to_dict(plan), "carry_write": "inplace"}
    start = threading.Barrier(4)
    errs = []

    def worker(i):
        try:
            start.wait()
            for j in range(12):
                cache.store(f"t{i}/k{j}", dict(rec))
                assert cache.lookup(f"t{i}/k{j}") is not None
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    fresh = PlanCache(path)
    for i in range(4):
        for j in range(12):
            assert fresh.lookup(f"t{i}/k{j}")["carry_write"] == "inplace"
