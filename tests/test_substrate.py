"""Substrate tests: optimizer, data determinism, checkpoint/restart,
gradient compression, serving engine."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property fuzzing needs the test extra; the rest of the module doesn't
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_smoke
from repro.data import BatchSpec, SyntheticLM
from repro.models import init_lm
from repro.models import ServeEngine
from repro.train import OptConfig, TrainConfig, Trainer
from repro.train.compress import compress_decompress, ef_init
from repro.train.optimizer import (adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule)


def test_adamw_minimises_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
                    clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(100)) - 0.1) < 1e-6
    assert float(lr(55)) < float(lr(20))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    cnorm = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(cnorm - 1.0) < 1e-4


def test_data_deterministic_and_host_sharded():
    spec = BatchSpec(global_batch=8, seq_len=16, vocab=100, n_hosts=1)
    d = SyntheticLM(spec, seed=3)
    a, b = d.batch_at(7), d.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(d.batch_at(8)["tokens"], a["tokens"])
    # host sharding: two hosts see disjoint slices but same structure
    s0 = SyntheticLM(BatchSpec(8, 16, 100, n_hosts=2, host_id=0), seed=3)
    s1 = SyntheticLM(BatchSpec(8, 16, 100, n_hosts=2, host_id=1), seed=3)
    assert s0.batch_at(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(s0.batch_at(0)["tokens"],
                              s1.batch_at(0)["tokens"])


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"count": jnp.int32(5)}}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, tree, {"next_step": 3})
    assert latest_step(d) == 3
    # partial .tmp dirs are never visible as checkpoints
    os.makedirs(os.path.join(d, "step_000009.tmp"))
    assert latest_step(d) == 3
    restored, extra, step = restore_checkpoint(d, 3, tree)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.arange(6.0).reshape(2, 3))
    assert extra["next_step"] == 3


def test_checkpoint_structure_mismatch_detected(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_async_checkpointer_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d, keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, {"x": jnp.full((2,), float(s))})
    ck.wait()
    assert latest_step(d) == 4
    kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(kept) == 2  # gc keeps last 2


def test_trainer_failure_recovery(tmp_path):
    cfg = get_smoke("nemotron_4_340b")
    spec = BatchSpec(global_batch=4, seq_len=16, vocab=cfg.vocab)
    data = SyntheticLM(spec, seed=0)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=40),
                       ckpt_every=5, ckpt_dir=str(tmp_path / "ck"),
                       log_every=1000)
    tr = Trainer(cfg, tcfg, data, fail_at_step=12)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        tr.run(20)
    tr2 = Trainer(cfg, tcfg, data)   # auto-resume
    assert tr2.step == 10            # latest complete checkpoint
    hist = tr2.run(5)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_trainer_loss_falls():
    cfg = get_smoke("h2o_danube_1_8b")
    spec = BatchSpec(global_batch=8, seq_len=32, vocab=cfg.vocab)
    tcfg = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=5, total_steps=60),
                       ckpt_every=10**9, ckpt_dir="/tmp/_unused_ck",
                       log_every=1000)
    tr = Trainer(cfg, tcfg, SyntheticLM(spec, seed=0))
    hist = tr.run(40)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.3, f"loss did not fall: {first} -> {last}"


def test_grad_accumulation_matches_large_batch():
    cfg = get_smoke("nemotron_4_340b")
    spec = BatchSpec(global_batch=8, seq_len=16, vocab=cfg.vocab)
    data = SyntheticLM(spec, seed=1)
    from repro.train.loop import make_train_step
    from repro.models import init_lm
    params = init_lm(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    t1 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=1)
    t2 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=4)
    s1 = make_train_step(cfg, t1)
    s2 = make_train_step(cfg, t2)
    clone = lambda t: jax.tree.map(lambda a: jnp.array(a), t)
    p1, _, _, m1 = s1(clone(params), adamw_init(params), jnp.zeros(()), batch)
    p2, _, _, m2 = s2(clone(params), adamw_init(params), jnp.zeros(()), batch)
    # same data -> nearly identical update (fp accumulation order differs)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_compression_error_feedback_bounded(seed):
        """EF invariant: residual stays bounded by one quantisation bucket."""
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10))
        err = jnp.zeros_like(g)
        for _ in range(5):
            deq, err = compress_decompress(g, err)
            scale = float(jnp.max(jnp.abs(g + err))) / 127.0
            assert float(jnp.max(jnp.abs(err))) <= scale * 0.5 + 1e-6


def test_straggler_deadline_counts():
    cfg = get_smoke("xlstm_350m")
    spec = BatchSpec(global_batch=2, seq_len=16, vocab=cfg.vocab)
    tcfg = TrainConfig(opt=OptConfig(), ckpt_every=10**9,
                       ckpt_dir="/tmp/_unused_ck2", log_every=1000,
                       step_deadline_s=1e-9)  # everything is a straggler
    tr = Trainer(cfg, tcfg, SyntheticLM(spec))
    tr.run(3)
    assert tr.straggler_events >= 1
