"""Stream schedule (repro.core.lower_stream): shift-register Pallas kernels.

Acceptance invariants for the streaming dataflow backend:
* numerically equivalent to the block schedule: single-step parity against
  the jnp oracle, and steps=4 *fused-loop* parity (1e-5) against
  ``schedule="block"`` for both paper kernels under zero AND periodic
  boundaries;
* the fused loop stays one compiled program on the stream path: the update
  rule traces exactly once regardless of N;
* ``strategy="tuned"`` can serve a stream-scheduled plan end to end from
  the cache (StreamSpec round-trip through compile);
* streaming is pallas-only and single-device (clear errors elsewhere).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (pw_advection, pw_advection_update, tracer_advection,
                        tracer_advection_update)
from repro.core import (PlanCache, TuneConfig, compile_program,
                        plan_to_dict, run_time_loop)
from repro.core.schedule import auto_plan
from repro.core.tune import cache_key


def pw_data(grid, seed=0):
    rng = np.random.default_rng(seed)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.1)
              for f in ("u", "v", "w")}
    scalars = {"tcx": jnp.float32(0.05), "tcy": jnp.float32(0.05)}
    coeffs = {c: jnp.asarray(
        np.linspace(0.9, 1.1, grid[2]).astype(np.float32))
        for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    return fields, scalars, coeffs


def tracer_data(grid, seed=1):
    rng = np.random.default_rng(seed)
    fields = {
        "t": jnp.asarray(rng.normal(size=grid).astype(np.float32) + 15.0),
        "un": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "vn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.2),
        "wn": jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.05),
        "e3t": jnp.asarray(
            np.abs(rng.normal(size=grid)).astype(np.float32) + 1.0),
        "msk": jnp.asarray(
            (rng.uniform(size=grid) > 0.05).astype(np.float32)),
    }
    scalars = {"rdt": jnp.float32(0.05), "zeps": jnp.float32(1e-6)}
    coeffs = {"ztfreez": jnp.asarray(np.full(grid[2], -1.8, np.float32))}
    return fields, scalars, coeffs


KERNELS = {
    "pw_advection": (pw_advection, pw_advection_update(0.1), pw_data,
                     (8, 8, 32)),
    "tracer_advection": (tracer_advection, tracer_advection_update(),
                         tracer_data, (6, 8, 32)),
}


# -------------------------------------------------- single-step vs oracle

@pytest.mark.parametrize("kernel", list(KERNELS))
@pytest.mark.parametrize("boundary", ["zero", "periodic"])
def test_stream_single_step_matches_oracle(kernel, boundary):
    prog_fn, _update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    fields, scalars, coeffs = data_fn(grid)
    ref = compile_program(p, grid, backend="jnp_fused")(fields, scalars,
                                                        coeffs)
    got = compile_program(p, grid, schedule="stream")(fields, scalars,
                                                      coeffs)
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(ref[k]), atol=1e-5, rtol=1e-5,
            err_msg=f"{kernel}/{boundary}/{k}")


# ------------------------------------- fused loop: stream vs block parity

@pytest.mark.parametrize("kernel", list(KERNELS))
@pytest.mark.parametrize("boundary", ["zero", "periodic"])
def test_stream_fused_loop_matches_block_schedule(kernel, boundary):
    """Acceptance: steps=4 fused-loop parity (1e-5) between the schedules
    for both paper kernels, zero and periodic."""
    prog_fn, update, data_fn, grid = KERNELS[kernel]
    p = prog_fn(boundary=boundary)
    fields, scalars, coeffs = data_fn(grid)
    blk = compile_program(p, grid, steps=4, update=update,
                          schedule="block")(fields, scalars, coeffs)
    stm = compile_program(p, grid, steps=4, update=update,
                          schedule="stream")(fields, scalars, coeffs)
    assert set(stm) == set(blk)
    for k in blk:
        np.testing.assert_allclose(
            np.asarray(stm[k]), np.asarray(blk[k]), atol=1e-5, rtol=1e-5,
            err_msg=f"{kernel}/{boundary}/{k}")


def test_stream_fused_loop_matches_host_loop():
    """...and against the host-driven reference, not just block-vs-stream."""
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    ex1 = compile_program(p, grid, schedule="stream")
    ref = run_time_loop(ex1, dict(fields), scalars, coeffs, 4, update)
    got = compile_program(p, grid, steps=4, update=update,
                          schedule="stream")(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------- trace once

def test_stream_update_traced_once():
    prog_fn, _update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    traces = {"n": 0}

    def counting_update(flds, out):
        traces["n"] += 1
        return {"u": flds["u"] + 0.1 * out["su"],
                "v": flds["v"] + 0.1 * out["sv"],
                "w": flds["w"] + 0.1 * out["sw"]}

    ex = compile_program(p, grid, steps=4, update=counting_update,
                         schedule="stream")
    ex(fields, scalars, coeffs)
    assert traces["n"] == 1
    ex(fields, scalars, coeffs)              # second call: jit cache hit
    assert traces["n"] == 1


# ------------------------------------------------ tuned plans + dispatch

def test_tuned_strategy_serves_stream_plan_from_cache():
    """A cached stream winner drives ``strategy="tuned"`` end to end: the
    StreamSpec survives the JSON round trip and the compile dispatches to
    the shift-register lowering with zero timed runs."""
    prog_fn, update, data_fn, grid = KERNELS["pw_advection"]
    p = prog_fn()
    fields, scalars, coeffs = data_fn(grid)
    plan = auto_plan(p, grid, schedule="stream")
    cache = PlanCache(path=None)
    key = cache_key(p, grid, "pallas", True, "float32", "loop")
    cache.store(key, {"plan": plan_to_dict(plan), "carry_write": "repad"})

    def no_timer(fn):                        # a timed run would be a bug
        raise AssertionError("cache hit must not measure")

    ex = compile_program(p, grid, strategy="tuned", steps=4, update=update,
                         tune_config=TuneConfig(timer=no_timer),
                         plan_cache=cache)
    assert ex.plan.schedule == "stream"
    assert ex.plan.stream is not None
    ref = compile_program(p, grid, steps=4, update=update,
                          schedule="block")(fields, scalars, coeffs)
    got = ex(fields, scalars, coeffs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-5, rtol=1e-5)


def test_stream_requires_pallas_backend():
    p = pw_advection()
    with pytest.raises(ValueError, match="pallas"):
        compile_program(p, (8, 8, 32), backend="jnp_fused",
                        schedule="stream")


def test_stream_composes_with_degenerate_mesh():
    # stream + mesh= is first-class; a 1x1 mesh must bit-match the local
    # stream lowering (the sharded path constant-folds to the same graph)
    from repro.dist.sharding import make_auto_mesh
    p = pw_advection()
    grid = (8, 8, 32)
    plan = auto_plan(p, grid, schedule="stream")
    rng = np.random.default_rng(3)
    fields = {f: rng.normal(size=grid).astype(np.float32) * 0.1
              for f in ("u", "v", "w")}
    scalars = {"tcx": 0.05, "tcy": 0.05}
    coeffs = {c: np.linspace(0.9, 1.1, grid[2]).astype(np.float32)
              for c in ("tzc1", "tzc2", "tzd1", "tzd2")}
    mesh = make_auto_mesh((1,), ("X",))
    got = compile_program(p, grid, plan=plan, mesh=mesh,
                          mesh_axes=("X", None, None))(fields, scalars,
                                                       coeffs)
    ref = compile_program(p, grid, plan=plan)(fields, scalars, coeffs)
    for k in ref:
        assert np.array_equal(np.asarray(got[k]), np.asarray(ref[k])), k
