"""Pure-jnp oracles for every Pallas kernel in this package.

``stencil3d`` kernels are validated against ``repro.core.lower_jnp``
(the Von-Neumann reference executes the same IR); this module adds the
attention oracle and re-exports the stencil one for the per-kernel tests.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core import lower_jnp


def stencil_reference(program, fields, scalars=None, coeffs=None):
    """Oracle for kernels built by stencil3d.build_group_call."""
    return lower_jnp.lower(program, mode="naive")(fields, scalars or {},
                                                  coeffs or {})


def swa_reference(q, k, v, *, window: int):
    """Dense masked causal sliding-window attention, f32 accumulation.

    q, k, v: (B, S, H, D) with H already GQA-repeated.
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = (kpos <= qpos) & (kpos > qpos - window)
    logits = jnp.where(ok[None, None], logits, -1e30)
    wgt = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", wgt, v.astype(jnp.float32))
    return out.astype(q.dtype)
