"""Generic Pallas stencil kernel builder (pl.pallas_call + BlockSpec).

This is the TPU materialisation of the paper's shift buffer + dataflow
structure, generated *from the IR* (nothing here is hand-specialised to a
particular stencil):

* **shift buffer**  -> each external input is fetched as an overlapping VMEM
  window (``Element``-indexed BlockSpec over a halo-padded HBM array).  The
  window holds *all* neighbourhood values an op may touch — the 3/9/27-value
  property of the paper's 1-/2-/3-D shift buffers (Fig. 2).
* **hls.dataflow stage concurrency** -> the Pallas grid pipeline: the DMA for
  grid step i+1 is in flight while step i computes and step i-1 stores
  (load_data / shift_buffer / compute / write_data overlap).
* **single load_data stage** -> every op in the fuse group slices the same
  VMEM windows; shared subtrees evaluate once (hash-consed memo).
* **per-field dataflow split** -> one output Ref per produced field; ops with
  in-group dependencies are recomputed on extended margins (overlapped
  tiling) exactly as planned by ``passes.infer_halo``.
* **small data -> BRAM** -> runtime scalars and the shard origin live in SMEM;
  1-D per-level coefficients ride in as lane-resident windows.
* **512-bit bursts** -> the planner lane-aligns the last block axis (x128).

Zero-halo semantics: margin-extended recompute is masked against the *global*
domain (the kernel receives the shard origin at runtime), so fused overlapped
tiling is bit-compatible with streamed per-field execution on any shard of a
distributed run.

Works identically under ``interpret=True`` (CPU validation) and compiled
Mosaic (TPU target).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

try:  # Element block dims: public in newer JAX, core in 0.8.x
    from jax.experimental.pallas import Element  # type: ignore

    def _window_spec(shape, index_map):
        return pl.BlockSpec(tuple(Element(s) for s in shape), index_map)
except ImportError:  # pragma: no cover
    try:
        from jax._src.pallas.core import Element  # type: ignore

        def _window_spec(shape, index_map):
            return pl.BlockSpec(tuple(Element(s) for s in shape), index_map)
    except ImportError:
        # jax 0.4.x: Unblocked indexing takes element offsets directly,
        # which is exactly what the overlapping-window maps emit.
        def _window_spec(shape, index_map):
            return pl.BlockSpec(tuple(shape), index_map,
                                indexing_mode=pl.Unblocked())

from ..core.expr_eval import evaluate
from ..core.ir import Access, Program
from ..core.passes import GroupHalo, infer_halo


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def build_group_call(p: Program, group: Sequence[int], block: Sequence[int],
                     grid_shape: Sequence[int], dtype=jnp.float32,
                     interpret: bool = True,
                     global_extent: Sequence[int] | None = None):
    """Build a callable(padded_inputs, scalars, coeffs, origin) -> outputs.

    ``padded_inputs`` must be padded by ``pad_lo``/``pad_hi`` (exposed on the
    returned callable).  ``origin`` gives the shard's global offset per axis
    (defaults to zeros); ``global_extent`` the global domain size (defaults
    to ``grid_shape``) — together they define the out-of-domain mask for
    margin-extended recompute.
    """
    ndim = p.ndim
    gh: GroupHalo = infer_halo(p, group)
    block = tuple(min(int(b), int(g)) for b, g in zip(block[:ndim], grid_shape))
    grid_shape = tuple(int(g) for g in grid_shape)
    if global_extent is None:
        global_extent = grid_shape
    global_extent = tuple(int(g) for g in global_extent)
    tiles = tuple(_cdiv(grid_shape[a], block[a]) for a in range(ndim))
    padded_out = tuple(tiles[a] * block[a] for a in range(ndim))
    halo_lo = tuple(int(gh.input_halo[a, 0]) for a in range(ndim))
    halo_hi = tuple(int(gh.input_halo[a, 1]) for a in range(ndim))
    align_hi = tuple(padded_out[a] - grid_shape[a] for a in range(ndim))
    win = tuple(block[a] + halo_lo[a] + halo_hi[a] for a in range(ndim))

    group = list(group)
    ops = [p.ops[i] for i in group]
    margins = {p.ops[i].out: gh.margins[i] for i in group}
    produced = {p.ops[i].out for i in group}
    n_scalars = len(p.scalars)
    scalar_index = {s: i for i, s in enumerate(p.scalars)}
    out_names = [op.out for op in ops if op.out in set(gh.group_outputs)]
    coeff_axis = {c: p.coeffs[c] for c in gh.group_coeffs}
    # which ops need the zero-halo mask on margin-extended recompute: a
    # periodic op's wraparound windows make the recomputed values exact at
    # every position, so masking them to zero would be wrong; a zero-BC
    # op's out-of-domain values must read as 0 downstream
    masked = {op.out: (margins[op.out].any()
                       and p.fields[op.out].boundary != "periodic")
              for op in ops}

    def kernel(*refs):
        i = 0
        s_ref = refs[i]; i += 1                      # scalars (SMEM, f32)
        org_ref = refs[i]; i += 1                    # shard origin (SMEM, i32)
        in_refs = {f: refs[i + k] for k, f in enumerate(gh.group_inputs)}
        i += len(gh.group_inputs)
        coeff_refs = {c: refs[i + k] for k, c in enumerate(gh.group_coeffs)}
        i += len(gh.group_coeffs)
        out_refs = {f: refs[i + k] for k, f in enumerate(out_names)}

        # single load_data stage: every window loads exactly once
        windows = {f: r[...] for f, r in in_refs.items()}
        coeff_windows = {c: r[...] for c, r in coeff_refs.items()}
        results: dict = {}
        memo: dict = {}

        def scalar(name: str):
            return s_ref[scalar_index[name]]

        for op in ops:
            m = margins[op.out]

            def coeff(c, m=m):
                ax = coeff_axis[c.coeff]
                start = int(gh.input_halo[ax, 0] - m[ax, 0] + c.offset)
                size = block[ax] + int(m[ax, 0]) + int(m[ax, 1])
                v = coeff_windows[c.coeff][start:start + size]
                shape = [1] * ndim
                shape[ax] = size
                return v.reshape(shape)

            def access(a: Access, m=m):
                sl = []
                if a.field in produced:
                    src = results[a.field]
                    pm = margins[a.field]
                    for ax in range(ndim):
                        start = int(pm[ax, 0] - m[ax, 0] + a.offset[ax])
                        size = block[ax] + int(m[ax, 0]) + int(m[ax, 1])
                        sl.append(slice(start, start + size))
                else:
                    src = windows[a.field]
                    for ax in range(ndim):
                        start = int(gh.input_halo[ax, 0] - m[ax, 0] + a.offset[ax])
                        size = block[ax] + int(m[ax, 0]) + int(m[ax, 1])
                        sl.append(slice(start, start + size))
                return src[tuple(sl)]

            # memo shared across ops at the same margin (hash-consed CSE);
            # different margins slice different extents
            mkey = tuple(int(v) for v in m.flatten())
            op_memo = memo.setdefault(mkey, {})
            res = evaluate(op.expr, access, scalar, op_memo, coeff=coeff)
            ext = tuple(block[ax] + int(m[ax, 0]) + int(m[ax, 1])
                        for ax in range(ndim))
            res = jnp.broadcast_to(jnp.asarray(res, dtype=dtype), ext)
            if masked[op.out]:
                # zero-halo semantics: recomputed values OUTSIDE the global
                # domain must read as 0 to downstream consumers.
                mask = None
                for ax in range(ndim):
                    g0 = (org_ref[ax] + pl.program_id(ax) * block[ax]
                          - int(m[ax, 0]))
                    coord = g0 + jax.lax.broadcasted_iota(jnp.int32, ext, ax)
                    ok = (coord >= 0) & (coord < global_extent[ax])
                    mask = ok if mask is None else (mask & ok)
                res = jnp.where(mask, res, jnp.asarray(0, dtype=dtype))
            results[op.out] = res
            if op.out in out_refs:
                center = tuple(slice(int(m[ax, 0]), int(m[ax, 0]) + block[ax])
                               for ax in range(ndim))
                out_refs[op.out][...] = res[center]

    def window_map(*idx):
        return tuple(idx[a] * block[a] for a in range(ndim))

    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),   # scalars
                pl.BlockSpec(memory_space=pltpu.SMEM)]   # origin
    for _ in gh.group_inputs:
        in_specs.append(_window_spec(
            tuple(win[a] for a in range(ndim)), window_map))
    for c in gh.group_coeffs:
        ax = coeff_axis[c]
        in_specs.append(_window_spec(
            (win[ax],),
            (lambda *idx, ax=ax: (idx[ax] * block[ax],))))
    out_specs = tuple(pl.BlockSpec(block, lambda *idx: tuple(idx))
                      for _ in out_names)
    out_shape = tuple(jax.ShapeDtypeStruct(padded_out, dtype) for _ in out_names)

    call = pl.pallas_call(
        kernel,
        grid=tiles,
        in_specs=in_specs,
        out_specs=out_specs if len(out_names) > 1 else out_specs[0],
        out_shape=out_shape if len(out_names) > 1 else out_shape[0],
        interpret=interpret,
    )

    crop = tuple(slice(0, grid_shape[a]) for a in range(ndim))

    expect = tuple(halo_lo[a] + padded_out[a] + halo_hi[a]
                   for a in range(ndim))

    def run(padded_inputs: dict, scalars_vec=None,
            padded_coeffs: dict | None = None, origin=None,
            input_pad: dict | None = None):
        """``input_pad[f]`` gives the (ndim, 2) padding the provided array
        actually carries when it exceeds this group's window geometry —
        e.g. a fused time loop's carry-resident persistent buffer sized for
        the worst consuming group.  The window is sliced out statically; no
        reallocation or copy of the halo slabs happens here."""
        svec = (scalars_vec if scalars_vec is not None
                else jnp.zeros((max(n_scalars, 1),), jnp.float32))
        org = (origin if origin is not None
               else jnp.zeros((ndim,), jnp.int32))
        args = [svec, org]
        for f in gh.group_inputs:
            x = padded_inputs[f]
            if input_pad is not None and f in input_pad:
                ip = input_pad[f]
                sl = tuple(slice(int(ip[a][0]) - halo_lo[a],
                                 int(ip[a][0]) - halo_lo[a] + expect[a])
                           for a in range(ndim))
                x = x[sl]
            args.append(x)
        for c in gh.group_coeffs:
            args.append(padded_coeffs[c])
        res = call(*args)
        if len(out_names) == 1:
            res = (res,)
        return {f: r[crop] for f, r in zip(out_names, res)}

    # geometry for orchestrators (lower_pallas pads with zeros; distribute
    # pads via halo exchange)
    run.group_inputs = gh.group_inputs
    run.group_outputs = out_names
    run.group_coeffs = gh.group_coeffs
    run.coeff_axis = coeff_axis
    run.block = block
    run.halo_lo = halo_lo
    run.halo_hi = halo_hi
    run.align_hi = align_hi
    run.pad_lo = halo_lo
    run.pad_hi = tuple(halo_hi[a] + align_hi[a] for a in range(ndim))
    run.window = win
    run.tiles = tiles
    run.vmem_window_bytes = int(np.prod(win)) * len(gh.group_inputs) * np.dtype(
        np.float32 if dtype == jnp.float32 else np.float16).itemsize
    return run
