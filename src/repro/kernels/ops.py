"""jit'd public wrappers for the Pallas kernels.

* :func:`stencil_apply` — compile + run a stencil Program through the
  generated dataflow kernels (the paper's main artifact).
* :func:`sliding_window_attention` — SWA with GQA handling; drop-in for the
  jnp path in ``models.layers`` when running on TPU (or validating in
  interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import compile_program
from .swa import swa_pallas


def stencil_apply(program, grid, fields, scalars=None, coeffs=None,
                  *, interpret: bool = True, strategy: str = "auto"):
    ex = compile_program(program, grid, backend="pallas",
                         interpret=interpret, strategy=strategy)
    return ex(fields, scalars or {}, coeffs or {})


@functools.partial(jax.jit, static_argnames=("window", "q_block",
                                             "interpret"))
def sliding_window_attention(q, k, v, *, window: int, q_block: int = 128,
                             interpret: bool = True):
    """q: (B,S,H,D); k, v: (B,S,KV,D) — KV heads repeated here for GQA."""
    H, KV = q.shape[2], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    return swa_pallas(q, k, v, window=window, q_block=q_block,
                      interpret=interpret)
