"""Sliding-window attention as a Pallas stencil kernel.

The paper's shift buffer applied to the sequence dimension: a query tile of
``Bq`` positions attends to KV positions ``[q0 - w, q0 + Bq)`` — an
*overlapping window* over the KV sequence, exactly the Element-indexed halo
window the stencil backend uses over grid axes (halo_lo = window, halo_hi =
0).  Each KV element is fetched into VMEM once per query tile instead of
once per query — the same reuse the FPGA shift register buys.

Grid: (batch, heads, q_tiles).  Block layout keeps the head dim on lanes
(Dh is 64..256 on the assigned archs) and the window on sublanes.  Softmax
is computed tile-locally (the whole window is in VMEM — no running-max
pass needed, unlike global flash attention).

Validated in interpret mode against ``ref.swa_reference``; on TPU the same
code lowers through Mosaic.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import Element  # type: ignore

    def _kv_spec(slab, D, index_map):
        return pl.BlockSpec((1, 1, Element(slab), Element(D)), index_map)
except ImportError:  # pragma: no cover
    try:
        from jax._src.pallas.core import Element  # type: ignore

        def _kv_spec(slab, D, index_map):
            return pl.BlockSpec((1, 1, Element(slab), Element(D)), index_map)
    except ImportError:
        # jax 0.4.x: fully element-indexed spec; the leading dims have block
        # size 1, so their element offsets coincide with block indices.
        def _kv_spec(slab, D, index_map):
            return pl.BlockSpec((1, 1, slab, D), index_map,
                                indexing_mode=pl.Unblocked())


def swa_pallas(q, k, v, *, window: int, q_block: int = 128,
               interpret: bool = True):
    """q, k, v: (B, S, H, D) with H already GQA-repeated.  Causal SWA.

    Returns (B, S, H, D).  ``window`` counts the current position, i.e.
    position i attends to (i-window, i].
    """
    B, S, H, D = q.shape
    w = int(window)
    Bq = min(q_block, S)
    if S % Bq:
        raise ValueError(f"S={S} not divisible by q_block={Bq}")
    nq = S // Bq
    slab = w + Bq                       # KV window per query tile
    scale = 1.0 / math.sqrt(D)

    # layout: (B, H, S, D) so the kernel tiles are (tile, D) matrices
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    # halo-pad the KV sequence on the left (zero halo; masked anyway)
    kp = jnp.pad(kt, ((0, 0), (0, 0), (w, 0), (0, 0)))
    vp = jnp.pad(vt, ((0, 0), (0, 0), (w, 0), (0, 0)))

    def kernel(q_ref, k_ref, v_ref, o_ref):
        i = pl.program_id(2)
        qb = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
        kb = k_ref[0, 0].astype(jnp.float32)          # (slab, D)
        vb = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            qb, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (Bq, slab)
        qpos = i * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, slab), 0)
        kpos = (i * Bq - w
                + jax.lax.broadcasted_iota(jnp.int32, (Bq, slab), 1))
        ok = (kpos <= qpos) & (kpos > qpos - w) & (kpos >= 0)
        logits = jnp.where(ok, logits, -1e30)
        m = logits.max(axis=1, keepdims=True)
        p = jnp.exp(logits - m)
        denom = p.sum(axis=1, keepdims=True)
        out = jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) / denom
        o_ref[0, 0] = out.astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq),
        in_specs=[
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
            _kv_spec(slab, D, lambda b, h, i: (b, h, i * Bq, 0)),
            _kv_spec(slab, D, lambda b, h, i: (b, h, i * Bq, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(qt, kp, vp)
    return out.transpose(0, 2, 1, 3)
