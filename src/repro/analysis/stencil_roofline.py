"""Modeled TPU performance for stencil programs (paper Fig. 4 analogue).

The FPGA paper's II=1 design is *streaming-bandwidth limited*: one result
per cycle with every input element fetched exactly once.  The TPU dataflow
backend has the same property (windows fetch each element once per fuse
group), so the model is:

    time/pt = max( bytes_per_point / HBM_bw,  flops_per_point / VPU_f32 )
    MPt/s   = 1e-6 / time_per_point    (per chip; x chips when distributed)

bytes_per_point per backend:
  * pallas (dataflow) — each group input read once, each group output
    written once (+halo fraction, negligible at production block sizes)
  * jnp_fused (DaCe role)   — inputs re-read per consuming op after XLA
    fusion boundaries: approximated as one read per field per op-cluster
  * jnp_naive (Vitis -O0 role) — one read per stencil ACCESS, one write per
    op (no reuse at all)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .. import hw
from ..core.ir import Program
from ..core.passes import infer_halo, live_ops, stage_split

# v5e vector unit f32 throughput (8x128 lanes x FMA x ~0.94 GHz) — estimate
VPU_F32_FLOPS = 7.5e12


@dataclasses.dataclass
class StencilModel:
    flops_per_point: float
    bytes_per_point: dict      # backend -> bytes
    mpts_chip: dict            # backend -> modeled MPt/s on one chip

    def mpts(self, backend: str, chips: int = 1) -> float:
        return self.mpts_chip[backend] * chips


def model_program(p: Program, dtype_bytes: int = 4) -> StencilModel:
    fl = p.flops_per_point()
    alive = live_ops(p)
    groups = stage_split(p, "auto")

    # dataflow: per group, each external input read once + outputs written
    reads = 0
    writes = 0
    for g in groups:
        gh = infer_halo(p, g)
        reads += len(gh.group_inputs) + len(gh.group_coeffs) * 0  # coeffs tiny
        writes += len(gh.group_outputs)
    dataflow_b = (reads + writes) * dtype_bytes

    # naive: one read per access, one write per op
    accesses = sum(len(p.ops[i].accesses()) for i in alive)
    naive_b = (accesses + len(alive)) * dtype_bytes

    # fused jnp: XLA fuses elementwise chains but rematerialises between
    # reduction/reshape boundaries; empirical middle ground — one read per
    # distinct field per op + one write per op
    fused_reads = sum(len({a.field for a in p.ops[i].accesses()})
                      for i in alive)
    fused_b = (fused_reads + len(alive)) * dtype_bytes

    bytes_pp = {"pallas": dataflow_b, "jnp_fused": fused_b,
                "jnp_naive": naive_b}
    mpts = {}
    for k, b in bytes_pp.items():
        t_mem = b / hw.TPU_V5E.hbm_bandwidth
        t_cmp = fl / VPU_F32_FLOPS
        mpts[k] = 1e-6 / max(t_mem, t_cmp)
    return StencilModel(flops_per_point=fl, bytes_per_point=bytes_pp,
                        mpts_chip=mpts)


def modeled_energy_j(points: float, mpts: float,
                     watts: float = hw.TPU_V5E.busy_watts) -> float:
    """Paper Fig. 5/6 analogue: energy = execution time x busy power."""
    seconds = points / (mpts * 1e6)
    return seconds * watts
