"""Modeled TPU performance for stencil programs (paper Fig. 4 analogue).

The FPGA paper's II=1 design is *streaming-bandwidth limited*: one result
per cycle with every input element fetched exactly once.  The TPU dataflow
backend has the same property (windows fetch each element once per fuse
group), so the model is:

    time/pt = max( bytes_per_point / HBM_bw,  flops_per_point / VPU_f32 )
    MPt/s   = 1e-6 / time_per_point    (per chip; x chips when distributed)

bytes_per_point per backend:
  * pallas (dataflow) — each group input read once, each group output
    written once (+halo fraction, negligible at production block sizes)
  * jnp_fused (DaCe role)   — inputs re-read per consuming op after XLA
    fusion boundaries: approximated as one read per field per op-cluster
  * jnp_naive (Vitis -O0 role) — one read per stencil ACCESS, one write per
    op (no reuse at all)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .. import hw
from ..core.ir import Program, count_flops
from ..core.passes import infer_halo, live_ops, stage_split

# v5e vector unit f32 throughput (8x128 lanes x FMA x ~0.94 GHz) — estimate
VPU_F32_FLOPS = 7.5e12

# Fixed cost of one stream-sweep grid step (window shift + DMA dispatch),
# amortised by spatial unrolling: a ``plane_tile = P`` sweep pays it only
# ``ceil(n_steps / P)`` times.  Rough estimate; it exists so the roofline
# can *rank* P honestly, not to predict absolute seconds.
STREAM_STEP_OVERHEAD_S = 5e-9


@dataclasses.dataclass
class StencilModel:
    flops_per_point: float
    bytes_per_point: dict      # backend -> bytes
    mpts_chip: dict            # backend -> modeled MPt/s on one chip

    def mpts(self, backend: str, chips: int = 1) -> float:
        return self.mpts_chip[backend] * chips


def model_program(p: Program, dtype_bytes: int = 4) -> StencilModel:
    fl = p.flops_per_point()
    alive = live_ops(p)
    groups = stage_split(p, "auto")

    # dataflow: per group, each external input read once + outputs written
    reads = 0
    writes = 0
    for g in groups:
        gh = infer_halo(p, g)
        reads += len(gh.group_inputs) + len(gh.group_coeffs) * 0  # coeffs tiny
        writes += len(gh.group_outputs)
    dataflow_b = (reads + writes) * dtype_bytes

    # naive: one read per access, one write per op
    accesses = sum(len(p.ops[i].accesses()) for i in alive)
    naive_b = (accesses + len(alive)) * dtype_bytes

    # fused jnp: XLA fuses elementwise chains but rematerialises between
    # reduction/reshape boundaries; empirical middle ground — one read per
    # distinct field per op + one write per op
    fused_reads = sum(len({a.field for a in p.ops[i].accesses()})
                      for i in alive)
    fused_b = (fused_reads + len(alive)) * dtype_bytes

    bytes_pp = {"pallas": dataflow_b, "jnp_fused": fused_b,
                "jnp_naive": naive_b}
    mpts = {}
    for k, b in bytes_pp.items():
        t_mem = b / hw.TPU_V5E.hbm_bandwidth
        t_cmp = fl / VPU_F32_FLOPS
        mpts[k] = 1e-6 / max(t_mem, t_cmp)
    return StencilModel(flops_per_point=fl, bytes_per_point=bytes_pp,
                        mpts_chip=mpts)


def plan_bytes_per_point(p: Program, plan, grid, graph=None) -> float:
    """Modeled HBM bytes per grid point for one plan's actual geometry.

    Schedule-aware (the reuse structure is the whole point of the plan
    dimension):

    * ``"block"`` — each fuse-group input is fetched as an overlapping
      window, so its traffic carries the halo overhead
      ``prod(window) / prod(block)``: a small block on a wide halo re-reads
      the overlap every tile.
    * ``"stream"`` — the shift-register sweep fetches **each input cell
      once per region sweep** (the paper's headline property); the only
      overhead is the padded halo ring itself, ``prod(padded extents) /
      prod(grid)``, which vanishes at production grids.  With temporal
      blocking (effective ``time_tile = T > 1`` on the graph) one sweep
      advances T time steps, so the whole sweep's traffic — inputs read
      through the T-deepened (chained) halo, outputs written once — is
      charged **once per T steps**: bytes/point/step shrinks ~1/T, which
      is exactly the reuse the tuner searches T for.

    Outputs are written once either way.  The jnp backends ignore plan
    geometry and collapse to :func:`model_program`'s backend-level numbers.
    """
    bs = hw.DTYPE_BYTES[plan.dtype]
    if plan.backend != "pallas":
        return float(model_program(p, dtype_bytes=bs)
                     .bytes_per_point[plan.backend])
    grid = [int(g) for g in grid]
    if getattr(plan, "schedule", "block") == "stream":
        if graph is None:
            from ..core.dataflow import lower_to_dataflow
            graph = lower_to_dataflow(p, plan, grid)
        T = max(1, int(getattr(graph, "time_tile", 1)))
        bytes_pp = 0.0
        # chained halos: the sweep's real fetch geometry under temporal
        # blocking (identical to the per-step halos at T = 1)
        for gh in graph.group_halos():
            padded = [grid[a] + int(gh.input_halo[a, 0])
                      + int(gh.input_halo[a, 1]) for a in range(p.ndim)]
            overhead = float(np.prod(padded)) / float(np.prod(grid))
            bytes_pp += (len(gh.group_inputs) * overhead * bs
                         + len(gh.group_outputs) * bs) / T
        return bytes_pp
    blk = np.minimum(np.asarray(plan.block[:p.ndim], dtype=np.int64),
                     np.asarray(grid, dtype=np.int64))
    blk = np.maximum(blk, 1)
    bytes_pp = 0.0
    for grp in plan.groups:
        gh = infer_halo(p, grp)
        win = blk + gh.input_halo[:, 0] + gh.input_halo[:, 1]
        overhead = float(np.prod(win)) / float(np.prod(blk))
        bytes_pp += len(gh.group_inputs) * overhead * bs
        bytes_pp += len(gh.group_outputs) * bs
    return bytes_pp


def _plan_flops_per_point(p: Program, plan, grid, graph=None) -> float:
    """Recompute-inflated flops/point: block margins extend every tile,
    stream margins only widen the non-stream axes of each plane (stream-axis
    dependencies ride in ring buffers, recompute-free).  A temporal chain
    (effective ``time_tile = T > 1``) runs every op once per chain stage;
    earlier stages compute over margin-extended planes (stage ``s`` adds
    ``(T-1-s)`` per-step halo reaches on the non-stream axes, mirroring the
    kernel's ``stage_margins``) so the redundant boundary work the chain
    trades for HBM traffic is priced in, amortised over the T steps one
    sweep advances."""
    grid = [int(g) for g in grid]
    if getattr(plan, "schedule", "block") == "stream":
        if graph is None:
            from ..core.dataflow import lower_to_dataflow
            graph = lower_to_dataflow(p, plan, grid)
        T = max(1, int(getattr(graph, "time_tile", 1)))
        flops_pp = 0.0
        plane = np.asarray(grid[1:], dtype=np.int64)
        for region in graph.regions:
            ih = region.halo.input_halo          # per-step reach
            step = ih[1:, 0] + ih[1:, 1]
            for s in range(T):
                acc = T - 1 - s
                for i in region.ops:
                    m = region.halo.margins[i]
                    ext = plane + m[1:, 0] + m[1:, 1] + acc * step
                    recompute = float(np.prod(ext)) / float(np.prod(plane))
                    flops_pp += count_flops(p.ops[i].expr) * recompute / T
        return flops_pp
    blk = np.minimum(np.asarray(plan.block[:p.ndim], dtype=np.int64),
                     np.asarray(grid, dtype=np.int64))
    blk = np.maximum(blk, 1)
    flops_pp = 0.0
    for grp in plan.groups:
        gh = infer_halo(p, grp)
        for i in grp:
            m = gh.margins[i]
            ext = blk + m[:, 0] + m[:, 1]
            recompute = float(np.prod(ext)) / float(np.prod(blk))
            flops_pp += count_flops(p.ops[i].expr) * recompute
    return flops_pp


def model_plan(p: Program, plan, grid) -> float:
    """Modeled seconds per time step for one *specific* plan (tuner pruner).

    :func:`model_program` prices the three backend roles; this prices a
    candidate :class:`~repro.core.schedule.DataflowPlan`'s actual geometry
    so the tuner can rank candidates *before* paying for a measurement —
    reuse-aware via :func:`plan_bytes_per_point` (stream schedules charge
    each input cell once per sweep, block schedules re-read window
    overlaps) and recompute-aware via the margin-extended flop count.

    The jnp backends ignore block shape and fuse groups, so their candidates
    collapse to the backend-level bytes/point of :func:`model_program`.

    The prediction is checked against reality by :mod:`repro.obs.achieved`:
    ``achieved_fraction = model_plan(...) * steps / measured_seconds`` rides
    on tune records, ``PlanChosen`` trace events and the smoke-benchmark
    rows, so the model's calibration drift is observable per commit
    (ROADMAP item 3).
    """
    pts = float(np.prod([int(g) for g in grid]))
    bs = hw.DTYPE_BYTES[plan.dtype]
    if plan.backend != "pallas":
        m = model_program(p, dtype_bytes=bs)
        return pts / (m.mpts(plan.backend) * 1e6)
    graph = None
    if getattr(plan, "schedule", "block") == "stream":
        # legalise once; both the bytes and flops terms consume it
        from ..core.dataflow import lower_to_dataflow
        graph = lower_to_dataflow(p, plan, grid)
    t_mem = (plan_bytes_per_point(p, plan, grid, graph=graph) * pts
             / hw.TPU_V5E.hbm_bandwidth)
    t_cmp = (_plan_flops_per_point(p, plan, grid, graph=graph) * pts
             / VPU_F32_FLOPS)
    t_step = 0.0
    if graph is not None:
        # per-grid-step sweep overhead, amortised P-fold by spatial
        # unrolling and spread over the T time steps one sweep advances
        T = max(1, int(getattr(graph, "time_tile", 1)))
        P = max(1, int(getattr(graph, "plane_tile", 1)))
        n_steps = int(grid[0])
        t_step = (len(graph.regions) * -(-n_steps // P)
                  * STREAM_STEP_OVERHEAD_S / T)
    return max(t_mem, t_cmp) + t_step


def modeled_energy_j(points: float, mpts: float,
                     watts: float = hw.TPU_V5E.busy_watts) -> float:
    """Paper Fig. 5/6 analogue: energy = execution time x busy power."""
    seconds = points / (mpts * 1e6)
    return seconds * watts
