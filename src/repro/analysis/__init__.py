from .roofline import (parse_collectives, roofline_report, analytic_flops,
                       RooflineTerms)
