"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh):

    compute    = FLOPs / (chips · peak_FLOP/s)
    memory     = bytes_moved / (chips · HBM_bw)
    collective = wire_bytes / (chips · link_bw)

Sources and the scan caveat
---------------------------
``compiled.cost_analysis()`` supplies HLO FLOPs/bytes and
``compiled.as_text()`` the collective inventory — but XLA counts a
``while``-loop (scan) body ONCE, so any scan-based program under-reports by
the trip count (verified on this container: an 8-step scanned matmul reports
1/8 the unrolled FLOPs).  Production models here scan over layers and over
sequence chunks, so the table reports BOTH:

  * ``*_hlo``       — as measured from the artifact (the brief's recipe)
  * ``*_corrected`` — HLO numbers with known static trip counts multiplied
                       back in (layer count; sequence-chunk counts), plus
                       analytic MODEL_FLOPS as the compute cross-check.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per trained token, plus
the quadratic attention term 12·L·H·S²·Dh·(window fraction) — the standard
MFU basis; the ratio MODEL_FLOPS/HLO_FLOPs flags remat/dispatch waste.

Collective wire-bytes per op (ring algorithms, group size g):
    all-gather       out_bytes · (g-1)/g
    reduce-scatter   out_bytes · (g-1)
    all-reduce       2 · out_bytes · (g-1)/g
    all-to-all       out_bytes · (g-1)/g
    collective-permute  out_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional


from .. import hw
from ..configs.base import ModelConfig, ShapeConfig

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.  bf16[256,4096]{1,0}  or  f32[]  or (tuple, ...) results
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> list:
    """Scan post-SPMD HLO for collective ops; returns per-instance records.

    Uses the RESULT shape(s) on each collective line plus the replica-group
    size to estimate ring wire bytes per device.  Each record carries the
    enclosing computation name so while-loop (scan) bodies can be multiplied
    by their trip counts.
    """
    # identify while-loop body/condition computations: referenced by
    # `while(...), condition=%c, body=%b` ops anywhere in the module
    loop_comps = set()
    for m in re.finditer(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                         hlo_text):
        loop_comps.update(m.groups())

    out = []
    comp = "entry"
    for line in hlo_text.splitlines():
        s = line.strip()
        cm = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{", s)
        if cm and not s.startswith("ROOT"):
            comp = cm.group(1)
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) + r")\(", s)
        if not m:
            continue
        result_ty, op = m.group(1), m.group(2)
        if "fusion" in s.split(op)[0] and op not in s:
            continue
        bytes_out = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(result_ty))
        g = 1
        gm = _GROUPS_RE.search(s)
        if gm:
            # replica_groups=[n_groups, group_size]<=[N]
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(s)
            if gl:
                g = len([x for x in gl.group(1).split(",") if x.strip()])
        if op == "collective-permute":
            wire = bytes_out     # point-to-point: no replica_groups attr
        elif g <= 1:
            wire = 0
        elif op == "all-gather":
            wire = bytes_out * (g - 1) // g
        elif op == "all-reduce":
            wire = 2 * bytes_out * (g - 1) // g
        elif op == "reduce-scatter":
            wire = bytes_out * (g - 1)
        elif op == "all-to-all":
            wire = bytes_out * (g - 1) // g
        else:  # collective-permute
            wire = bytes_out
        out.append({"op": op, "bytes": bytes_out, "group": g, "wire": wire,
                    "comp": comp,
                    "in_loop": (comp in loop_comps or "while" in comp
                                or "body" in comp)})
    return out


# --------------------------------------------------------------------------
# analytic model FLOPs
# --------------------------------------------------------------------------

def _attn_flops_per_layer(cfg: ModelConfig, S: int, B: int, kind: str,
                          causal_half=True) -> float:
    ctx = min(cfg.window, S) if (kind == "local" and cfg.window) else S
    # scores + weighted sum: 2 * 2 * B * H * S * ctx * Dh  (x0.5 causal)
    f = 4.0 * B * cfg.n_heads * S * ctx * cfg.d_head
    return f * (0.5 if causal_half and ctx == S else 1.0)


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Forward/step FLOPs (per executed step, whole cluster)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens = B            # one new token per sequence
        S_ctx = S
    else:
        tokens = B * S
        S_ctx = S
    n_active = cfg.num_active_params()
    matmul_fwd = 2.0 * n_active * tokens
    attn = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if cfg.family == "xlstm":
            continue
        if shape.kind == "decode":
            ctx = min(cfg.window, S_ctx) if (kind == "local" and cfg.window) \
                else S_ctx
            attn += 4.0 * B * cfg.n_heads * ctx * cfg.d_head
        else:
            attn += _attn_flops_per_layer(cfg, S, B, kind)
    fwd = matmul_fwd + attn
    if shape.kind == "train":
        return {"fwd": fwd, "total": 3.0 * fwd,   # bwd = 2x fwd
                "model_flops": 6.0 * n_active * tokens + 3 * attn}
    return {"fwd": fwd, "total": fwd,
            "model_flops": 2.0 * n_active * tokens + attn}


# --------------------------------------------------------------------------
# report assembly
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    def as_dict(self):
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant}


def roofline_report(*, chips: int, cost: dict, hlo_text: str,
                    scan_correction: float = 1.0,
                    model_flops: float | None = None,
                    analytic: Optional[dict] = None,
                    spec=hw.TPU_V5E) -> dict:
    """Build the three terms from a compiled artifact.

    ``cost`` is ``compiled.cost_analysis()`` (per-device numbers);
    ``scan_correction`` is the layer-scan trip count — applied ONLY to
    while-body collectives (exact) and, as a documented approximation, to
    total HLO flops/bytes (upper bound when non-loop work exists).
    ``analytic`` supplies {'bytes_per_dev', 'wire_per_dev'} from the
    traffic model in :func:`analytic_traffic` for the primary terms.
    """
    coll = parse_collectives(hlo_text)
    wire_raw = sum(c["wire"] for c in coll)
    wire_corr = sum(c["wire"] * (scan_correction if c["in_loop"] else 1.0)
                    for c in coll)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    hlo = RooflineTerms(
        compute_s=flops_dev / spec.peak_bf16_flops,
        memory_s=bytes_dev / spec.hbm_bandwidth,
        collective_s=wire_raw / spec.ici_link_bandwidth)
    corr = RooflineTerms(
        compute_s=flops_dev * scan_correction / spec.peak_bf16_flops,
        memory_s=bytes_dev * scan_correction / spec.hbm_bandwidth,
        collective_s=wire_corr / spec.ici_link_bandwidth)

    report = {
        "chips": chips,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "scan_correction": scan_correction,
        "collectives": _summarise(coll),
        "wire_per_dev_hlo": wire_raw,
        "wire_per_dev_loop_corrected": wire_corr,
        "terms_hlo": hlo.as_dict(),
        "terms_corrected": corr.as_dict(),
    }
    if model_flops is not None:
        report["model_flops_total"] = model_flops
        mf_dev = model_flops / chips
        report["model_compute_s"] = mf_dev / spec.peak_bf16_flops
        denom = flops_dev * scan_correction * chips
        report["useful_flops_ratio"] = (model_flops / denom
                                        if denom else float("nan"))
    if analytic is not None:
        primary = RooflineTerms(
            compute_s=(model_flops / chips / spec.peak_bf16_flops
                       if model_flops else hlo.compute_s),
            memory_s=analytic["bytes_per_dev"] / spec.hbm_bandwidth,
            collective_s=max(wire_corr, analytic["wire_per_dev"])
            / spec.ici_link_bandwidth)
        report["analytic_bytes_per_dev"] = analytic["bytes_per_dev"]
        report["analytic_wire_per_dev"] = analytic["wire_per_dev"]
        report["terms_primary"] = primary.as_dict()
    return report


def _summarise(coll: list) -> dict:
    agg: dict = {}
    for c in coll:
        a = agg.setdefault(c["op"], {"count": 0, "bytes": 0, "wire": 0})
        a["count"] += 1
        a["bytes"] += c["bytes"]
        a["wire"] += c["wire"]
    return agg


# --------------------------------------------------------------------------
# analytic traffic model (HBM bytes + ICI wire per device)
# --------------------------------------------------------------------------

def analytic_traffic(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                     tp: int, fsdp: int, dp_total: int,
                     remat: bool = True) -> dict:
    """Documented first-principles traffic model per device per step.

    HBM bytes (train):
      params      fwd read 2·P_bf16 + bwd read 2·P_bf16 (post-gather copies)
                  + optimizer: read P_f32+mu+nu, write P_f32+mu+nu
                  + grads f32 write+read — sharded terms /(fsdp·tp)
      activations c_act r/w passes of L·B_loc·S·D·2 bytes; remat doubles the
                  forward-activation traffic; attention adds score traffic
                  2·B_loc·H_loc·S·ctx·2 per layer (flash: logits never hit
                  HBM — counted once at bf16)
      logits      4 passes of B_loc·S·V_tp·4
    HBM bytes (decode): whole (sharded) param set read once per token +
      KV cache read/write + small activations.
    ICI wire (per device):
      TP  : fwd 2 AR + bwd 2 AR per layer of B_loc·S·D·2 -> 2·bytes·(g-1)/g
      FSDP: params all-gather fwd+bwd 2·2·P_shard_bf16·(g-1) ... expressed
            on the gathered size; grad reduce-scatter 4·P·(g-1)/g /g
      DP(pod): grad all-reduce of the fsdp shard 2·(4P/fsdp)·(g-1)/g
    Capacity-drop MoE buffers are counted at capacity_factor.
    """
    B, S = shape.global_batch, shape.seq_len
    L, D = cfg.n_layers, cfg.d_model
    P = cfg.num_params()
    P_active = cfg.num_active_params()
    dp = max(dp_total, 1)
    B_loc = max(B // dp, 1)
    V_tp = cfg.vocab // tp if cfg.vocab % tp == 0 else cfg.vocab
    H_loc = max(cfg.n_heads // tp, 1)
    tok_loc = B_loc * (1 if shape.kind == "decode" else S)

    # ---------------- HBM ----------------
    if shape.kind == "train":
        p_sh = P / (fsdp * tp) if fsdp else P / tp
        params_b = (2 * 2 * P_active / tp * 2  # fwd+bwd reads of gathered bf16
                    + 8 * p_sh               # grads f32 write+read
                    + (4 + 4 + 4) * p_sh     # opt reads p,mu,nu
                    + (4 + 4 + 4) * p_sh)    # opt writes p,mu,nu
        act_pass = 2.0 if remat else 1.0     # recompute doubles fwd traffic
        c_act = 14.0                         # proj/norm/residual r+w passes
        acts_b = (1 + act_pass) * c_act * L * tok_loc * D * 2
        attn_b = 0.0
        for i in range(L):
            ctx = min(cfg.window, S) if (cfg.layer_kind(i) == "local"
                                         and cfg.window) else S
            # fwd + 2x bwd passes over the (never-materialised-in-HBM-if-
            # flash) score tile traffic, counted once at bf16
            attn_b += 3 * 2.0 * B_loc * H_loc * S * ctx * 2
        if cfg.family == "xlstm":
            attn_b = 0.0
        logits_b = 4.0 * tok_loc * V_tp * 4
        bytes_dev = params_b + acts_b + attn_b + logits_b
    elif shape.kind == "prefill":
        params_b = 2 * P_active / tp
        acts_b = 14.0 * L * tok_loc * D * 2
        attn_b = 0.0
        for i in range(L):
            ctx = min(cfg.window, S) if (cfg.layer_kind(i) == "local"
                                         and cfg.window) else S
            attn_b += 2.0 * B_loc * H_loc * S * ctx * 2
        cache_b = 2 * L * B_loc * S * max(cfg.n_kv_heads // tp, 1) \
            * cfg.d_head * 2
        bytes_dev = params_b + acts_b + attn_b + cache_b + tok_loc * V_tp * 4
    else:  # decode: memory-bound by params + cache
        params_b = 2 * P_active / tp
        cache_tot = 0.0
        shard = tp if (cfg.n_kv_heads % tp == 0 or cfg.d_head % tp == 0) \
            else 1
        for i in range(L):
            kind = cfg.layer_kind(i)
            if cfg.family == "xlstm":
                di = cfg.ssm_expand * D
                cache_tot += 2 * B_loc * (di / tp) * (di // cfg.n_heads) * 4
                continue
            ctx = min(cfg.window, S) if (kind == "local" and cfg.window) \
                else S
            # read K and V over the context each step (+1 slot write)
            cache_tot += 2 * B_loc * ctx * cfg.n_kv_heads * cfg.d_head \
                * 2 / shard
        acts_b = 14.0 * L * B_loc * D * 2
        bytes_dev = params_b + cache_tot + acts_b + B_loc * V_tp * 4

    # ---------------- ICI wire ----------------
    wire = 0.0
    act_bytes = tok_loc * D * 2
    if tp > 1:
        n_ar = 4 if shape.kind == "train" else 2     # fwd(+bwd) ARs
        wire += n_ar * L * 2 * act_bytes * (tp - 1) / tp
        # logits all-reduce for the loss (train) or sampling gather
        wire += 2 * tok_loc * 4 * (tp - 1) / tp * (2 if shape.kind == "train"
                                                   else 1)
    if shape.kind == "train" and fsdp > 1:
        p_bf16 = 2 * P_active / tp
        wire += 2 * p_bf16 * (fsdp - 1) / fsdp       # AG fwd + bwd ~ 2x
        wire += 4 * P / tp * (fsdp - 1) / fsdp / 1   # grad reduce-scatter f32
    pod = dp / fsdp if (shape.kind == "train" and fsdp) else dp
    if shape.kind == "train" and pod > 1:
        wire += 2 * (4 * P / (tp * max(fsdp, 1))) * (pod - 1) / pod
    return {"bytes_per_dev": float(bytes_dev), "wire_per_dev": float(wire)}
