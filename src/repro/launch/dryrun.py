import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # SPMD remat warnings off

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes, record memory and
cost analysis + roofline terms.

The two lines above MUST run before any jax import: jax locks the device
count at first initialisation.  Smoke tests and benches never import this
module, so they see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k --pods 1
    PYTHONPATH=src python -m repro.launch.dryrun --all --pods both \
        --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax  # noqa: E402  (after XLA_FLAGS on purpose)

from repro.analysis.roofline import (analytic_flops, analytic_traffic,
                                     roofline_report)
from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.specs import build_step

# long_500k needs sub-quadratic attention: skip for pure full-attention
# archs (noted in DESIGN.md §Arch-applicability); run for SWA/SSM/hybrid.
FULL_ATTN_ARCHS = {"grok_1_314b", "nemotron_4_340b", "chameleon_34b",
                   "whisper_small"}


def cell_is_skipped(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch in FULL_ATTN_ARCHS:
        return "skip:full-attention arch (sub-quadratic required)"
    return None


def scan_correction(cfg, shape, microbatches: int = 1) -> float:
    """Known outer-scan trip counts multiplied back into HLO flops/bytes.

    Train/score paths scan over layers and over grad-accumulation
    microbatches (bodies counted once by XLA cost analysis).  Prefill/decode
    paths are layer-unrolled (factor 1); inner sequence-chunk scans (flash
    KV chunks, SSM chunks) remain under-counted and are flagged in notes.
    """
    if shape.kind == "train":
        return float(cfg.n_layers) * float(microbatches)
    return 1.0


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip:
        rec["status"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = make_rules(mesh, kind=("train" if shape.kind == "train"
                                   else "serve"), variant=variant)
    t0 = time.time()
    step, args, in_sh = build_step(cfg, shape, rules)
    mb = getattr(step, "microbatches", 1)
    out_sh = getattr(step, "out_shardings", None)
    jit_kwargs = {"in_shardings": in_sh}
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    with mesh:
        lowered = jax.jit(step, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per module
        cost = cost[0]
    hlo = compiled.as_text()
    af = analytic_flops(cfg, shape)
    tp = mesh.shape["model"] if rules.tp else 1
    fsdp = (chips // tp if (shape.kind == "train" and rules.fsdp) else 1)
    dp_total = chips // tp
    traffic = analytic_traffic(cfg, shape, chips=chips, tp=tp, fsdp=fsdp,
                               dp_total=dp_total)
    rep = roofline_report(chips=chips, cost=cost, hlo_text=hlo,
                          scan_correction=scan_correction(cfg, shape, mb),
                          model_flops=af["model_flops"], analytic=traffic)
    rec.update({
        "status": "ok",
        "chips": chips,
        "microbatches": mb,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total": int(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "analytic_flops": af,
        "roofline": rep,
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def summarise(rec: dict) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
                f"{rec['status']}")
    m = rec["memory"]["per_device_total"] / 2**30
    t = rec["roofline"].get("terms_primary",
                            rec["roofline"]["terms_corrected"])
    return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} ok "
            f"mem/dev={m:6.2f}GiB compute={t['compute_s']:.2e}s "
            f"memory={t['memory_s']:.2e}s coll={t['collective_s']:.2e}s "
            f"dom={t['dominant']:10s} "
            f"(compile {rec['compile_s']:.0f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--pods", default="1", choices=["1", "2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="'+'-joined levers: sp, dp_remap, kvseq")
    args = ap.parse_args()

    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"1": [False], "2": [True], "both": [False, True]}[args.pods]
    out = None if args.no_save else args.out

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    rec = run_cell(arch, shape, mp, out, args.variant)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"FAIL {type(e).__name__}: {e}"}
                    traceback.print_exc()
                    if out:
                        os.makedirs(out, exist_ok=True)
                        with open(os.path.join(
                                out, f"{arch}__{shape}__{rec['mesh']}.json"),
                                "w") as f:
                            json.dump(rec, f, indent=1)
                print(summarise(rec), flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
