"""ShapeDtypeStruct stand-ins + sharded step builders for the dry-run.

Everything here is allocation-free: ``jax.eval_shape`` produces parameter /
optimizer / cache trees as ShapeDtypeStructs, and the step functions are
``jax.jit(...).lower(...)``-ed against them with explicit in/out shardings.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, SHAPES, ShapeConfig
from ..dist.sharding import (ShardingRules, activation_context, cache_specs,
                             named_shardings, param_specs)
from ..models import (decode_step, init_cache, init_lm, init_whisper,
                      lm_loss, prefill)
from ..models.whisper import (whisper_decode_step, whisper_init_cache,
                              whisper_loss, whisper_prefill)
from ..train.optimizer import OptConfig, adamw_init, adamw_update


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def params_shapes(cfg: ModelConfig, inference: bool = False,
                  unstacked: bool = False):
    init = init_whisper if cfg.family == "encdec" else init_lm
    shapes = jax.eval_shape(functools.partial(init, cfg),
                            jax.random.PRNGKey(0))
    if inference:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), shapes)
    if unstacked and cfg.family != "encdec":
        # serving layout: strip the leading layer axis into a per-layer list
        blocks = shapes.pop("blocks")
        layer = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), blocks)
        shapes["layers"] = [layer] * cfg.n_layers
    return shapes


def _batch_axes_spec(rules: ShardingRules, batch: int):
    """Batch PartitionSpec entry, guarding divisibility (B=1 cells)."""
    axes = [a for a in rules.batch_axes()]
    total = 1
    for a in axes:
        total *= rules.mesh.shape[a]
    if axes and batch % total == 0:
        return tuple(axes) if len(axes) > 1 else axes[0]
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules):
    """ShapeDtypeStructs + NamedShardings for every model input of the cell."""
    mesh = rules.mesh
    B, S = shape.global_batch, shape.seq_len
    bspec = _batch_axes_spec(rules, B)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    tok_sh = NamedSharding(mesh, P(bspec, None))
    out = {"tokens": (tok, tok_sh)}
    if shape.kind == "train":
        out["labels"] = (tok, tok_sh)
    if cfg.family == "encdec":
        fr = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        out["frames"] = (fr, NamedSharding(mesh, P(bspec, None, None)))
    if shape.kind == "decode":
        one = jax.ShapeDtypeStruct((B,), jnp.int32)
        out["tokens"] = (one, NamedSharding(mesh, P(bspec)))
        out["pos"] = (jax.ShapeDtypeStruct((), jnp.int32),
                      NamedSharding(mesh, P()))
    return out


# --------------------------------------------------------------------------
# step builders (lower-ready)
# --------------------------------------------------------------------------

def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                      rules: ShardingRules, budget_bytes=6 * 2**30) -> int:
    """Gradient-accumulation factor so the per-layer saved residuals
    (L · B_loc/mb · S · D · 2 bytes) fit the activation budget."""
    dp = 1
    for a in rules.batch_axes():
        dp *= rules.mesh.shape[a]
    b_loc = max(shape.global_batch // dp, 1)
    tp = rules.mesh.shape[rules.tp] if rules.tp else 1
    h_loc = (cfg.n_heads // tp) if cfg.n_heads % tp == 0 else cfg.n_heads
    mb = 1
    while mb < b_loc:
        saved = (cfg.n_layers * (b_loc / mb) * shape.seq_len
                 * cfg.d_model * 2)
        # flash-attention f32 score tiles (~3 live copies in the bwd
        # recompute); chunk = 2048 in AttnSpec
        chunk = min(2048, shape.seq_len)
        flash = 3 * (b_loc / mb) * h_loc * shape.seq_len * chunk * 4
        if saved + flash <= budget_bytes:
            break
        mb *= 2
    return mb


def build_train_step(cfg: ModelConfig, shape: ShapeConfig,
                     rules: ShardingRules, remat: bool = True,
                     microbatches: int | None = None):
    """Returns (fn, example_args, in_shardings) for jit/lower."""
    opt_cfg = OptConfig()
    if microbatches is None:
        microbatches = auto_microbatches(cfg, shape, rules)
    mb = microbatches
    pshapes = params_shapes(cfg)
    ps = param_specs(cfg, pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s), ps,
                          is_leaf=lambda s: isinstance(s, P))
    oshapes = jax.eval_shape(adamw_init, pshapes)
    oshard = {"mu": pshard, "nu": pshard,
              "count": NamedSharding(rules.mesh, P())}
    ins = input_specs(cfg, shape, rules)

    def accumulate(loss_fn, params, *batch_parts):
        """Gradient accumulation: scan over mb microbatch slices."""
        if mb == 1:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, *batch_parts)
            return loss, grads

        split = [x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
                 for x in batch_parts]

        def acc(carry, xs):
            g_acc, l_acc = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, *xs)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
            return (g, l_acc + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), tuple(split))
        return loss / mb, jax.tree.map(lambda g: g / mb, grads)

    if cfg.family == "encdec":
        def loss_fn(params, frames, tokens, labels):
            return whisper_loss(cfg, params, frames, tokens, labels,
                                remat=remat)

        def step(params, opt_state, frames, tokens, labels):
            with activation_context(rules):
                loss, grads = accumulate(loss_fn, params, frames, tokens,
                                         labels)
                params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                                     opt_state)
            return params, opt_state, loss

        args = (pshapes, oshapes, ins["frames"][0], ins["tokens"][0],
                ins["labels"][0])
        in_sh = (pshard, oshard, ins["frames"][1], ins["tokens"][1],
                 ins["labels"][1])
    else:
        def loss_fn(params, tokens, labels):
            return lm_loss(cfg, params, tokens, labels, remat=remat)

        def step(params, opt_state, tokens, labels):
            with activation_context(rules):
                loss, grads = accumulate(loss_fn, params, tokens, labels)
                params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                                     opt_state)
            return params, opt_state, loss

        args = (pshapes, oshapes, ins["tokens"][0], ins["labels"][0])
        in_sh = (pshard, oshard, ins["tokens"][1], ins["labels"][1])
    step.microbatches = mb
    return step, args, in_sh


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       rules: ShardingRules):
    pshapes = params_shapes(cfg, inference=True)
    ps = param_specs(cfg, pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s), ps,
                          is_leaf=lambda s: isinstance(s, P))
    ins = input_specs(cfg, shape, rules)
    max_len = shape.seq_len

    if cfg.family == "encdec":
        def step(params, frames, tokens):
            with activation_context(rules):
                return whisper_prefill(cfg, params, frames, tokens, max_len)
        args = (pshapes, ins["frames"][0], ins["tokens"][0])
        in_sh = (pshard, ins["frames"][1], ins["tokens"][1])
    else:
        def step(params, tokens):
            with activation_context(rules):
                return prefill(cfg, params, tokens, max_len)
        args = (pshapes, ins["tokens"][0])
        in_sh = (pshard, ins["tokens"][1])
    return step, args, in_sh


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                      rules: ShardingRules):
    """serve_step: one new token against a KV cache of length seq_len."""
    pshapes = params_shapes(cfg, inference=True, unstacked=True)
    ps = param_specs(cfg, pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s), ps,
                          is_leaf=lambda s: isinstance(s, P))
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cshapes = jax.eval_shape(
            functools.partial(whisper_init_cache, cfg, B, S))
    else:
        cshapes = jax.eval_shape(functools.partial(init_cache, cfg, B, S))
    cspec = cache_specs(cfg, cshapes, rules)
    cshard = jax.tree.map(lambda s: NamedSharding(rules.mesh, s), cspec,
                          is_leaf=lambda s: isinstance(s, P))
    ins = input_specs(cfg, shape, rules)
    dec = whisper_decode_step if cfg.family == "encdec" else decode_step

    def step(params, cache, tokens, pos):
        with activation_context(rules):
            return dec(cfg, params, cache, tokens, pos)

    args = (pshapes, cshapes, ins["tokens"][0], ins["pos"][0])
    in_sh = (pshard, cshard, ins["tokens"][1], ins["pos"][1])
    step.out_shardings = (None, cshard)   # pin the returned cache layout
    return step, args, in_sh


def build_step(cfg, shape, rules, remat=True):
    if shape.kind == "train":
        return build_train_step(cfg, shape, rules, remat=remat)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, rules)
    return build_decode_step(cfg, shape, rules)
