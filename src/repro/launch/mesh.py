"""Production mesh definitions (deliverable e).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run (and only the dry-run) sets
``--xla_force_host_platform_device_count=512`` before any jax import.

Single pod: (16, 16) = 256 chips, axes (data, model)   — v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the "pod"
axis is pure data parallelism over DCN/ICI-superpod links.
"""

from __future__ import annotations

import jax

from ..dist.sharding import ShardingRules, make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_rules(mesh, *, kind: str = "train", variant: str = "baseline",
               seq_sharding: bool = False) -> ShardingRules:
    """Sharding rules per workload kind.

    train: TP over 'model', FSDP over 'data', DP over ('pod','data').
    serve: TP over 'model', params replicated over 'data' (no per-token
           FSDP gathers), batch over ('pod','data').

    ``variant`` composes hillclimb levers with '+':
      sp       — sequence-parallel activations (Megatron-SP)
      dp_remap — no TP: treat the whole mesh as data parallel, FSDP over
                 every axis (right answer for small models)
      kvseq    — shard KV caches over the length dim (flash-decoding
                 across chips)
    """
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else ("data",)
    levers = set(variant.split("+"))
    tp = "model"
    fsdp = "data" if kind == "train" else None
    kv_seq = "kvseq" in levers
    if "sp" in levers:
        seq_sharding = True
    if "dp_remap" in levers:
        tp = None
        dp = dp + ("model",)
        fsdp = (("data", "model") if kind == "train" else None)
    return ShardingRules(
        mesh=mesh, tp=tp, fsdp=fsdp, dp=dp, seq_sharding=seq_sharding,
        kv_seq_shard=kv_seq)


def stencil_mesh_axes(mesh):
    """Grid-axis -> mesh-axis mapping for distributed stencils:
    x over 'data', y over 'model', z over 'pod' (if present)."""
    if "pod" in mesh.axis_names:
        return ("data", "model", "pod")
    return ("data", "model", None)
