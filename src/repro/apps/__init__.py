from .advection import (pw_advection, pw_advection_update,  # noqa: F401
                        tracer_advection, tracer_advection_update)
