from .advection import pw_advection, tracer_advection  # noqa: F401
