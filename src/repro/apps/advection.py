"""The paper's two evaluation kernels as stencil IR programs (§4).

* :func:`pw_advection` — the Piacsek–Williams advection scheme from the Met
  Office MONC atmospheric model: 3 stencil computations across 3 wind fields
  (u, v, w) producing 3 source terms (su, sv, sw), with per-level
  coefficients tzc1/tzc2/tzd1/tzd2 ("small data") and scalar tcx/tcy.
  Structure follows Brown 2021 [4] / the MONC kernel the paper benchmarks.

* :func:`tracer_advection` — the NEMO ocean-model tracer-advection benchmark
  from PSycloneBench: 24 stencil computations across 6 fields with deep
  producer->consumer chains (a MUSCL-style upwind scheme: slopes, limited
  slopes, directional fluxes, divergence updates).  The exact NEMO geometry
  factors are replaced by representative coefficients; the *structure* —
  op count, field count, dependency depth, subselection-style Select ops
  (which StencilFlow could not express, §4) — matches the benchmark's role
  in the paper.

Axis convention: (i, j, k) = (x, y, z) with k the contiguous lane axis.
"""

from __future__ import annotations

from ..core.frontend import ProgramBuilder, absolute, maximum, minimum, sign, where
from ..core.ir import Program


def pw_advection(boundary: str = "zero") -> Program:
    """``boundary="periodic"`` builds the torus-domain variant (every field
    wraps; same IR, same plans, different halo fill on every backend)."""
    b = ProgramBuilder("pw_advection", ndim=3, boundary=boundary)
    u, v, w = b.inputs("u", "v", "w")
    tcx, tcy = b.scalars("tcx", "tcy")
    tzc1, tzc2 = b.coeff("tzc1", axis=2), b.coeff("tzc2", axis=2)
    tzd1, tzd2 = b.coeff("tzd1", axis=2), b.coeff("tzd2", axis=2)
    su, sv, sw = b.outputs("su", "sv", "sw")

    # --- su: u-momentum source ------------------------------------------
    b.define(su,
        tcx * (u[-1, 0, 0] * (u[0, 0, 0] + u[-1, 0, 0])
               - u[0, 0, 0] * (u[1, 0, 0] + u[0, 0, 0]))
        + tcy * (u[0, -1, 0] * (v[0, -1, 0] + v[1, -1, 0])
                 - u[0, 0, 0] * (v[0, 0, 0] + v[1, 0, 0]))
        + tzc1[0] * u[0, 0, -1] * (w[0, 0, -1] + w[1, 0, -1])
        - tzc2[0] * u[0, 0, 0] * (w[0, 0, 0] + w[1, 0, 0]))

    # --- sv: v-momentum source ------------------------------------------
    b.define(sv,
        tcx * (v[-1, 0, 0] * (u[-1, 0, 0] + u[-1, 1, 0])
               - v[0, 0, 0] * (u[0, 0, 0] + u[0, 1, 0]))
        + tcy * (v[0, -1, 0] * (v[0, 0, 0] + v[0, -1, 0])
                 - v[0, 0, 0] * (v[0, 1, 0] + v[0, 0, 0]))
        + tzc1[0] * v[0, 0, -1] * (w[0, 0, -1] + w[0, 1, -1])
        - tzc2[0] * v[0, 0, 0] * (w[0, 0, 0] + w[0, 1, 0]))

    # --- sw: w-momentum source ------------------------------------------
    b.define(sw,
        tcx * (w[-1, 0, 0] * (u[-1, 0, 0] + u[-1, 0, 1])
               - w[0, 0, 0] * (u[0, 0, 0] + u[0, 0, 1]))
        + tcy * (w[0, -1, 0] * (v[0, -1, 0] + v[0, -1, 1])
                 - w[0, 0, 0] * (v[0, 0, 0] + v[0, 0, 1]))
        + tzd1[0] * w[0, 0, -1] * (w[0, 0, 0] + w[0, 0, -1])
        - tzd2[0] * w[0, 0, 0] * (w[0, 0, 1] + w[0, 0, 0]))
    return b.build()


def pw_advection_update(dt: float = 0.1):
    """Forward-Euler wind update for :func:`pw_advection` — the canonical
    time-stepping rule shared by the examples, benchmarks and the fused
    ``compile_program(..., steps=N, update=...)`` path."""
    def update(fields, out):
        return {"u": fields["u"] + dt * out["su"],
                "v": fields["v"] + dt * out["sv"],
                "w": fields["w"] + dt * out["sw"]}
    return update


def tracer_advection_update():
    """Tracer carry rule for :func:`tracer_advection`: the corrected tracer
    becomes next step's ``t``; velocities and metrics are steady."""
    def update(fields, out):
        return dict(fields, t=out["ta"])
    return update


def tracer_advection(boundary: str = "zero") -> Program:
    """24 stencil ops / 6 input fields, MUSCL-style, with dependency chains.

    ``boundary="periodic"`` builds the torus-domain variant."""
    b = ProgramBuilder("tracer_advection", ndim=3, boundary=boundary)
    # 6 fields: tracer, 3 velocity components, 2 metric/mask fields
    t, un, vn, wn, e3t, msk = b.inputs("t", "un", "vn", "wn", "e3t", "msk")
    rdt, zeps = b.scalars("rdt", "zeps")
    ztfreez = b.coeff("ztfreez", axis=2)   # per-level reference
    # intermediates (temps) and the stored result
    names = ["zdx", "zdy", "zdz",              # raw slopes          (3)
             "zsx", "zsy", "zsz",              # limited slopes      (3)
             "zfx", "zfy", "zfz",              # upwind fluxes       (3)
             "zdivx", "zdivy", "zdivz",        # flux divergences    (3)
             "zta1",                           # first update        (1)
             "zdx2", "zdy2", "zdz2",           # second-pass slopes  (3)
             "zsx2", "zsy2", "zsz2",           # limited again       (3)
             "zfx2", "zfy2", "zfz2",           # corrected fluxes    (3)
             "zdiv2"]                          # corrector divergence(1)
    tmp = {n: b.temp(n) for n in names}
    ta = b.output("ta")                        # final op -> 24 total

    T = lambda n: tmp[n]

    # -- first pass: slopes ------------------------------------------------
    b.define(T("zdx"), (t[1, 0, 0] - t[0, 0, 0]) * msk[0, 0, 0])
    b.define(T("zdy"), (t[0, 1, 0] - t[0, 0, 0]) * msk[0, 0, 0])
    b.define(T("zdz"), (t[0, 0, 1] - t[0, 0, 0]) * msk[0, 0, 0])

    # -- slope limiting (minmod-like, uses Select/abs/sign) ----------------
    def limit(s, name):
        d0 = s[0, 0, 0]
        dm = {"zsx": s[-1, 0, 0], "zsy": s[0, -1, 0], "zsz": s[0, 0, -1]}[name]
        return where(d0 * dm > 0.0,
                     sign(d0) * minimum(absolute(d0), absolute(dm)),
                     0.0)

    b.define(T("zsx"), limit(T("zdx"), "zsx"))
    b.define(T("zsy"), limit(T("zdy"), "zsy"))
    b.define(T("zsz"), limit(T("zdz"), "zsz"))

    # -- upwind fluxes ------------------------------------------------------
    def flux(vel, s, t_up_off, ax):
        up = t[tuple(-1 if a == ax else 0 for a in range(3))]
        ce = t[0, 0, 0]
        sm = s[tuple(-1 if a == ax else 0 for a in range(3))]
        sc = s[0, 0, 0]
        v0 = vel[0, 0, 0]
        pos = v0 * (up + 0.5 * sm)      # upstream reconstruction
        neg = v0 * (ce - 0.5 * sc)
        return where(v0 > 0.0, pos, neg)

    b.define(T("zfx"), flux(un, T("zsx"), -1, 0))
    b.define(T("zfy"), flux(vn, T("zsy"), -1, 1))
    b.define(T("zfz"), flux(wn, T("zsz"), -1, 2))

    # -- divergences --------------------------------------------------------
    b.define(T("zdivx"), (T("zfx")[1, 0, 0] - T("zfx")[0, 0, 0]) / (e3t[0, 0, 0] + zeps))
    b.define(T("zdivy"), (T("zfy")[0, 1, 0] - T("zfy")[0, 0, 0]) / (e3t[0, 0, 0] + zeps))
    b.define(T("zdivz"), (T("zfz")[0, 0, 1] - T("zfz")[0, 0, 0]) / (e3t[0, 0, 0] + zeps))

    # -- first (predictor) update, with per-level freezing floor -----------
    b.define(T("zta1"),
             maximum(t[0, 0, 0] - rdt * (T("zdivx")[0, 0, 0]
                                         + T("zdivy")[0, 0, 0]
                                         + T("zdivz")[0, 0, 0]),
                     ztfreez[0]))

    # -- second (corrector) pass on the predicted tracer -------------------
    b.define(T("zdx2"), (T("zta1")[1, 0, 0] - T("zta1")[0, 0, 0]) * msk[0, 0, 0])
    b.define(T("zdy2"), (T("zta1")[0, 1, 0] - T("zta1")[0, 0, 0]) * msk[0, 0, 0])
    b.define(T("zdz2"), (T("zta1")[0, 0, 1] - T("zta1")[0, 0, 0]) * msk[0, 0, 0])

    def limit2(s, name):
        d0 = s[0, 0, 0]
        dm = {"zsx2": s[-1, 0, 0], "zsy2": s[0, -1, 0], "zsz2": s[0, 0, -1]}[name]
        return where(d0 * dm > 0.0,
                     sign(d0) * minimum(absolute(d0), absolute(dm)),
                     0.0)

    b.define(T("zsx2"), limit2(T("zdx2"), "zsx2"))
    b.define(T("zsy2"), limit2(T("zdy2"), "zsy2"))
    b.define(T("zsz2"), limit2(T("zdz2"), "zsz2"))

    def flux2(vel, s, ax):
        up = T("zta1")[tuple(-1 if a == ax else 0 for a in range(3))]
        ce = T("zta1")[0, 0, 0]
        sm = s[tuple(-1 if a == ax else 0 for a in range(3))]
        sc = s[0, 0, 0]
        v0 = vel[0, 0, 0]
        return where(v0 > 0.0, v0 * (up + 0.5 * sm), v0 * (ce - 0.5 * sc))

    b.define(T("zfx2"), flux2(un, T("zsx2"), 0))
    b.define(T("zfy2"), flux2(vn, T("zsy2"), 1))
    b.define(T("zfz2"), flux2(wn, T("zsz2"), 2))

    b.define(T("zdiv2"),
             (T("zfx2")[1, 0, 0] - T("zfx2")[0, 0, 0]
              + T("zfy2")[0, 1, 0] - T("zfy2")[0, 0, 0]
              + T("zfz2")[0, 0, 1] - T("zfz2")[0, 0, 0]) / (e3t[0, 0, 0] + zeps))

    b.define(ta,
             (0.5 * (t[0, 0, 0] + T("zta1")[0, 0, 0])
              - 0.5 * rdt * T("zdiv2")[0, 0, 0]) * msk[0, 0, 0])
    return b.build()
