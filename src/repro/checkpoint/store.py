"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000100.tmp/ ... -> atomic rename -> <dir>/step_000100/
        manifest.json            pytree structure, shapes, dtypes, writer info
        host0000.npz             this host's leaf shards (flattened paths)

Properties needed at 1000-node scale:
* **atomic publish** — readers only ever see complete checkpoints (tmp dir +
  rename; rename is atomic on POSIX).
* **async** — ``AsyncCheckpointer`` snapshots device arrays to host memory
  synchronously (cheap) and writes in a background thread; training resumes
  immediately.  ``wait()`` joins before the next save or on exit.
* **restartability** — ``latest_step`` scans for the newest complete step;
  a crashed/partial save never wins.
* **elastic restore** — arrays are saved unsharded-logically (per-leaf full
  value on host 0 in this single-process container; per-host shards with
  ``addressable_shards`` in multi-process runs) and restored with *whatever
  sharding the new mesh dictates* via ``jax.device_put`` — a job can come
  back on a different topology.
* **integrity** — leaf count + shape/dtype check against the manifest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = leaf
    return out


def _treedef_paths(tree):
    return list(_flatten(tree).keys())


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Synchronous sharded save with atomic publish."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "time": time.time()}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[path] = arr
        manifest["leaves"][path] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "host0000.npz"),
             **{k.replace("/", "__"): v for k, v in arrays.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for n in os.listdir(ckpt_dir):
        if n.startswith("step_") and not n.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, n, "manifest.json")):
                steps.append(int(n.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; reshard via ``shardings``
    (a matching pytree of NamedSharding) if given — the elastic path."""
    name = f"step_{step:08d}"
    d = os.path.join(ckpt_dir, name)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "host0000.npz"))
    flat_like = _flatten(like)
    if set(manifest["leaves"]) != set(flat_like):
        missing = set(flat_like) ^ set(manifest["leaves"])
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    shard_flat = _flatten(shardings) if shardings is not None else {}
    out = {}
    for path, leaf in flat_like.items():
        arr = data[path.replace("/", "__")]
        want = manifest["leaves"][path]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"{path}: corrupt shard {arr.shape} != {want['shape']}")
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        if path in shard_flat and shard_flat[path] is not None:
            out[path] = jax.device_put(arr, shard_flat[path])
        else:
            out[path] = jnp.asarray(arr)
    # rebuild tree
    flat_kp = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, _ in flat_kp[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        leaves.append(out[path])
    tree = jax.tree_util.tree_unflatten(flat_kp[1], leaves)
    return tree, manifest["extra"], manifest["step"]


class AsyncCheckpointer:
    """Background-thread writer with at-most-one outstanding save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        # snapshot to host synchronously: the device buffers may be donated
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except Exception as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
