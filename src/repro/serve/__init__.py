"""repro.serve — the stencil serving subsystem.

(The LM serving engine formerly here lives in ``repro.models.lm_serve``.)
"""

from ..core.schedule import BucketSpec, bucket_fingerprint, bucket_for
from .bucket import (crop, embed_coeff, embed_field, embed_request,
                     make_refresh, serving_program, size_scalar_names,
                     wrap_update)
from .engine import ServeResult, StencilEngine, StencilRequest
from .stats import ServeStats

__all__ = [
    "BucketSpec", "bucket_fingerprint", "bucket_for",
    "crop", "embed_coeff", "embed_field", "embed_request",
    "make_refresh", "serving_program", "size_scalar_names", "wrap_update",
    "ServeResult", "StencilEngine", "StencilRequest", "ServeStats",
]
