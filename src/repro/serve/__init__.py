from .engine import ServeEngine, sample_token
