"""Serving metrics — the serve-scoped view over a metrics registry.

``ServeStats`` keeps the attribute API the engine and tests have always
used (``stats.completed += 1``, ``stats.p99_ms()``, ``snapshot()``), but
every counter/gauge/latency sample now lives in a
:class:`repro.obs.metrics.MetricsRegistry` (``stats.registry``), so the
serving numbers export through the same snapshot machinery as the
compile-side metrics and the tracer.

All mutation happens either on the worker thread or under the engine's
submit lock, so plain registry instruments suffice; ``snapshot()`` returns
a plain JSON-serialisable dict for logging/benchmark rows.
"""

from __future__ import annotations

from ..obs.metrics import MetricsRegistry

#: integer counters, in the order ``snapshot()`` reports them
_COUNTERS = (
    "submitted", "completed", "failed", "timeouts",
    # executor-table hits vs builds (a build may still reuse a stored plan)
    "exec_hits", "exec_misses",
    # PlanCache serve-record hits vs misses on executor build
    "plan_hits", "plan_misses",
    # LRU evictions from the executor table (``max_executors`` cap)
    "evictions",
    "traces",            # update-rule traces observed (0 when warm)
    "compiles",          # executor builds that ran compile_program
    "batches", "batched_requests",
    "padded_slots",      # replicated filler slots across all batches
)

_GAUGES = ("wall_s",)    # time spent inside batch execution

#: capped latency reservoir (steady-state quantiles, not all-time)
LATENCY_WINDOW = 4096


class ServeStats:
    """Engine counters as registry-backed attributes.

    ``ServeStats(registry=...)`` scopes the instruments into a shared
    registry (e.g. to merge several engines into one snapshot); the
    default is a private registry per stats object."""

    def __init__(self, registry: MetricsRegistry | None = None):
        reg = registry if registry is not None else MetricsRegistry()
        object.__setattr__(self, "registry", reg)
        for n in _COUNTERS:
            reg.counter(n)
        for n in _GAUGES:
            reg.gauge(n)
        reg.histogram("latency_ms", maxlen=LATENCY_WINDOW)

    # attribute API: reads return plain numbers, writes set the instrument
    # (so ``stats.completed += 1`` mutates the registry counter)
    def __getattr__(self, name: str):
        reg = self.__dict__["registry"]
        if name in _COUNTERS:
            return reg.counter(name).value
        if name in _GAUGES:
            return reg.gauge(name).value
        raise AttributeError(f"ServeStats has no metric {name!r}")

    def __setattr__(self, name: str, value) -> None:
        reg = self.__dict__["registry"]
        if name in _COUNTERS:
            reg.counter(name).set(value)
        elif name in _GAUGES:
            reg.gauge(name).set(value)
        else:
            object.__setattr__(self, name, value)

    # ------------------------------------------------------------------
    def record_latency(self, ms: float) -> None:
        self.registry.histogram("latency_ms").observe(float(ms))

    def reset_latencies(self) -> None:
        """Drop recorded latencies (e.g. after a warm-up phase, so the
        quantiles describe steady-state traffic, not compiles)."""
        self.registry.histogram("latency_ms").clear()

    # ------------------------------------------------------------------
    def cache_hit_rate(self) -> float:
        n = self.exec_hits + self.exec_misses
        return self.exec_hits / n if n else 0.0

    def occupancy(self) -> float:
        """Mean fraction of batch slots holding real requests."""
        slots = self.batched_requests + self.padded_slots
        return self.batched_requests / slots if slots else 0.0

    def throughput(self) -> float:
        """Completed requests per second of batch-execution wall time."""
        return self.completed / self.wall_s if self.wall_s > 0 else 0.0

    def latency_quantile(self, q: float) -> float:
        return self.registry.histogram("latency_ms").quantile(q)

    def p50_ms(self) -> float:
        return self.latency_quantile(0.50)

    def p99_ms(self) -> float:
        return self.latency_quantile(0.99)

    def snapshot(self) -> dict:
        d = {n: getattr(self, n) for n in _COUNTERS + _GAUGES}
        d.update(hit_rate=self.cache_hit_rate(), occupancy=self.occupancy(),
                 throughput=self.throughput(), p50_ms=self.p50_ms(),
                 p99_ms=self.p99_ms(),
                 latencies=len(self.registry.histogram("latency_ms")))
        return d
