"""Serving metrics — counters the engine maintains and tests assert on.

All mutation happens either on the worker thread or under the engine's
submit lock, so plain ints suffice; ``snapshot()`` returns a plain dict
for logging/benchmark rows.
"""

from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass
class ServeStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    timeouts: int = 0

    # executor-table hits vs builds (a build may still reuse a persisted plan)
    exec_hits: int = 0
    exec_misses: int = 0
    # PlanCache serve-record hits vs misses on executor build
    plan_hits: int = 0
    plan_misses: int = 0
    # LRU evictions from the executor table (``max_executors`` cap)
    evictions: int = 0

    traces: int = 0          # update-rule traces observed (0 when warm)
    compiles: int = 0        # executor builds that ran compile_program

    batches: int = 0
    batched_requests: int = 0
    padded_slots: int = 0    # replicated filler slots across all batches

    wall_s: float = 0.0      # time spent inside batch execution

    def __post_init__(self):
        self._lat_ms = collections.deque(maxlen=4096)

    def record_latency(self, ms: float) -> None:
        self._lat_ms.append(float(ms))

    def reset_latencies(self) -> None:
        """Drop recorded latencies (e.g. after a warm-up phase, so the
        quantiles describe steady-state traffic, not compiles)."""
        self._lat_ms.clear()

    # ------------------------------------------------------------------
    def cache_hit_rate(self) -> float:
        n = self.exec_hits + self.exec_misses
        return self.exec_hits / n if n else 0.0

    def occupancy(self) -> float:
        """Mean fraction of batch slots holding real requests."""
        slots = self.batched_requests + self.padded_slots
        return self.batched_requests / slots if slots else 0.0

    def throughput(self) -> float:
        """Completed requests per second of batch-execution wall time."""
        return self.completed / self.wall_s if self.wall_s > 0 else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self._lat_ms:
            return 0.0
        xs = sorted(self._lat_ms)
        i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[i]

    def p50_ms(self) -> float:
        return self.latency_quantile(0.50)

    def p99_ms(self) -> float:
        return self.latency_quantile(0.99)

    def snapshot(self) -> dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        d.update(hit_rate=self.cache_hit_rate(), occupancy=self.occupancy(),
                 throughput=self.throughput(), p50_ms=self.p50_ms(),
                 p99_ms=self.p99_ms(), latencies=len(self._lat_ms))
        return d
