"""StencilEngine — batched, cached, concurrent stencil execution.

The serving layer turns the compile pipeline into a long-lived service:
requests (program, fields, steps, boundary) arrive on a bounded queue, a
single worker thread micro-batches them, and each distinct *bucket*
(program fingerprint x lane-quantised grid bucket x backend/compile options
x update rule) is compiled exactly once — warm requests re-trace nothing.

Three layers of reuse, coarsest first:

1. **executor table** (in-memory): ``bucket key -> _BucketExecutor`` holding
   the jitted, ``vmap``-batched executable.  A hot request is a dict lookup.
2. **plan records** (:class:`~repro.core.tune.PlanCache`): on an executor
   build the engine consults the persistent cache for a serving record
   (:func:`~repro.core.tune.read_serve_record`) and rebuilds from the stored
   plan without re-planning; a build that had to plan stores its record so
   the *next process* skips the work.  Stale-schema records miss cleanly.
3. **shape buckets** (:mod:`repro.serve.bucket`): request grids round up to
   quantised buckets and grid sizes enter the trace as scalars, so mixed
   request shapes share executors and batch together under ``vmap``.

Threading model: ``submit`` may be called from any thread (it only
validates, keys, and enqueues); all JAX work happens on the one worker
thread, so executors and stats need no locking of their own.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .. import hw
from ..core.ir import Program
from ..core.pipeline import CompileOptions, compile_program
from ..core.schedule import BucketSpec, bucket_fingerprint, bucket_for
from ..core.tune import PlanCache, make_serve_record, read_serve_record
from ..obs.events import CacheHit, CacheMiss, ExecutorEvicted
from ..obs.trace import current_tracer, resolve_tracer
from .bucket import embed_request, serving_program, wrap_update
from .stats import ServeStats


@dataclasses.dataclass
class StencilRequest:
    """One unit of serving work.

    ``fields`` are real-grid arrays (the grid is their common shape);
    ``steps`` + ``update`` select the fused time loop (result = final
    fields), both None selects a single application (result = program
    outputs).  ``update_key`` names the update rule for executor keying —
    required whenever two *different* rules share a qualname (lambdas,
    closures built per call); it defaults to the rule's qualified name.
    ``boundary`` overrides the program's declarations as in
    ``compile_program``.  ``timeout`` (seconds) expires the request if it
    is still queued when the deadline passes.
    """

    program: Program
    fields: Mapping
    scalars: Mapping | None = None
    coeffs: Mapping | None = None
    steps: int | None = None
    update: Callable | None = None
    update_key: str | None = None
    boundary: object = None
    timeout: float | None = None

    def grid(self) -> tuple:
        shapes = {tuple(np.shape(v)) for v in self.fields.values()}
        if len(shapes) != 1:
            raise ValueError(f"request fields disagree on grid: {shapes}")
        return next(iter(shapes))


@dataclasses.dataclass
class ServeResult:
    outputs: dict                 # real-grid arrays (cropped out of bucket)
    bucket: BucketSpec
    key: str
    latency_ms: float
    batch_size: int               # real requests in the executed batch


@dataclasses.dataclass
class _Item:
    req: StencilRequest
    program: Program              # serving program (boundary applied)
    spec: BucketSpec
    key: str
    future: Future
    submitted: float
    deadline: float | None


class _BucketExecutor:
    """One compiled bucket: the raw executable plus its batched jit."""

    def __init__(self, program, spec, steps, batched, unbatched_raw, plan,
                 carry_write):
        self.program = program
        self.spec = spec
        self.steps = steps
        self.batched = batched
        self._raw = unbatched_raw
        self.plan = plan
        self.carry_write = carry_write
        self.vmap_failed = False

    def fallback_unrolled(self):
        """Replace the vmapped dispatch with a jitted unrolled batch (the
        escape hatch for lowerings without a batching rule)."""
        raw = self._raw

        def unrolled(fields, scalars, coeffs):
            n = next(iter(fields.values())).shape[0]
            outs = [raw({f: v[i] for f, v in fields.items()},
                        {s: v[i] for s, v in scalars.items()},
                        {c: v[i] for c, v in coeffs.items()})
                    for i in range(n)]
            return {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}

        self.batched = jax.jit(unrolled)
        self.vmap_failed = True


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class StencilEngine:
    """Async serving front over the compile pipeline.

    ``submit`` returns a :class:`concurrent.futures.Future` resolving to a
    :class:`ServeResult`; ``run`` is the synchronous one-request helper.
    ``autostart=False`` leaves the worker thread unstarted (requests queue
    up; call :meth:`start` to begin draining — used by the bounded-queue
    tests and by callers that want to pre-fill a batch).

    Compile knobs may arrive loose (``backend=``, ``schedule=``, ``mesh=``
    + ``mesh_axes=``, ``time_tile=``, ...) or bundled in an
    ``options=CompileOptions(...)``; the options object seeds any knob the
    caller left at its engine default, and a knob set both ways with
    different values is an error.  ``mesh=`` makes every executor a
    sharded (``shard_map``) executable — the mesh topology is part of
    :func:`~repro.core.schedule.bucket_fingerprint`, so the same program
    served on different meshes occupies distinct executor-table entries
    and plan records.  ``max_executors=`` puts an LRU cap on the executor
    table: lookups refresh recency, an insert over the cap evicts the
    coldest executor (and the jitted traces it holds), counted in
    ``stats.evictions``.
    """

    #: compile knobs the engine shares with :class:`CompileOptions`; an
    #: ``options=`` object seeds these, loose kwargs override (a loose
    #: kwarg moved off its engine default that *disagrees* with the
    #: options value is an error, mirroring ``compile_program``).
    _OPTION_KNOBS = (("backend", "jnp_fused"), ("interpret", True),
                     ("schedule", None), ("strategy", "auto"),
                     ("dtype", "float32"), ("mesh", None),
                     ("mesh_axes", None), ("time_tile", None),
                     ("plane_tile", None))

    def __init__(self, *, backend: str = "jnp_fused", interpret: bool = True,
                 schedule: str | None = None, strategy: str = "auto",
                 dtype: str = "float32", mesh=None,
                 mesh_axes: tuple | None = None, time_tile: int | None = None,
                 plane_tile: int | None = None,
                 options: CompileOptions | None = None, max_batch: int = 8,
                 window_s: float = 0.002, queue_depth: int = 64,
                 max_executors: int | None = None,
                 plan_cache: PlanCache | None = None, lane: int = hw.LANE,
                 autostart: bool = True, tracer=None):
        loose = dict(backend=backend, interpret=interpret, schedule=schedule,
                     strategy=strategy, dtype=dtype, mesh=mesh,
                     mesh_axes=mesh_axes, time_tile=time_tile,
                     plane_tile=plane_tile)
        co_defaults = {f.name: f.default
                       for f in dataclasses.fields(CompileOptions)}
        for name, default in self._OPTION_KNOBS:
            val = loose[name]
            if options is not None:
                oval = getattr(options, name)
                if val == default:
                    val = oval      # options seeds every untouched knob
                elif oval != co_defaults[name] and oval != val:
                    raise ValueError(
                        f"{name} passed both ways with different values: "
                        f"engine {name}={val!r} vs options.{name}={oval!r}")
            setattr(self, name, val)
        if self.mesh is not None and self.mesh_axes is None:
            raise ValueError("mesh= requires mesh_axes= (one entry per grid "
                             "axis; None leaves an axis unsharded)")
        self.max_batch = int(max_batch)
        self.window_s = float(window_s)
        self.max_executors = (None if max_executors is None
                              else int(max_executors))
        if self.max_executors is not None and self.max_executors < 1:
            raise ValueError("max_executors must be >= 1 (or None for "
                             "unbounded)")
        self.plan_cache = plan_cache
        self.lane = int(lane)
        # the engine's tracer is captured at construction (worker threads
        # can't see the submitting thread's ambient tracer): ``tracer=``
        # pins one, ``tracer=True`` installs a fresh recording tracer,
        # None inherits whatever is ambient *now* (usually the no-op)
        self.tracer = (current_tracer() if tracer is None
                       else resolve_tracer(tracer))
        self.stats = ServeStats()
        self._q: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        # LRU over compiled buckets: hits refresh recency, inserts evict
        # the coldest entry once over ``max_executors``.  Evicting an
        # executor also drops its jitted traces (the batched/unbatched
        # callables it holds), so the cap bounds the trace cache too.
        self._executors: collections.OrderedDict = collections.OrderedDict()
        self._traces = [0]
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._np_dtype = np.dtype(self.dtype)
        if autostart:
            self.start()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker,
                                            name="stencil-serve", daemon=True)
            self._thread.start()

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=30)
        while True:
            try:
                it = self._q.get_nowait()
            except queue.Empty:
                break
            it.future.set_exception(RuntimeError("engine closed"))
            self.stats.failed += 1

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # request front
    # ------------------------------------------------------------------
    def describe(self, req: StencilRequest):
        """Validate a request and resolve its serving identity:
        ``(serving_program, BucketSpec, executor key)`` — exactly what the
        worker will compile and cache under.  Useful for pre-warming and
        for tests poking at the plan cache."""
        if (req.steps is None) != (req.update is None):
            raise ValueError("steps and update go together: both set "
                             "(fused loop) or both None (single apply)")
        p = req.program
        if req.boundary is not None:
            p = p.with_boundary(req.boundary)
        if req.steps is not None and self.mesh is not None and any(
                self.mesh_axes[a] is not None
                and int(self.mesh.shape[self.mesh_axes[a]]) > 1
                for a in range(p.ndim)):
            per = sorted(f for f in p.input_fields()
                         if p.boundaries().get(f) == "periodic")
            if per:
                raise ValueError(
                    f"fused serving of periodic fields {per} under mesh= is "
                    "not supported: the bucket refresh is a global torus "
                    "gather with no shard-local form; serve them unsharded "
                    "or use boundary='zero'")
        sp = serving_program(p)
        missing = set(sp.input_fields()) - set(req.fields)
        if missing:
            raise ValueError(f"request missing input fields {sorted(missing)}")
        missing = set(p.scalars) - set(req.scalars or {})
        if missing:
            raise ValueError(f"request missing scalars {sorted(missing)}")
        spec = bucket_for(sp, req.grid(), lane=self.lane)
        ukey = req.update_key
        if ukey is None:
            ukey = ("none" if req.update is None else
                    f"{req.update.__module__}.{req.update.__qualname__}")
        key = "|".join([
            bucket_fingerprint(sp, spec.bucket, backend=self.backend,
                               dtype=self.dtype, interpret=self.interpret,
                               schedule=self.schedule, steps=req.steps,
                               mesh=self.mesh, mesh_axes=self.mesh_axes,
                               plane_tile=self.plane_tile),
            f"update={ukey}",
            f"jax={jax.__version__}",
        ])
        return sp, spec, key

    def submit(self, req: StencilRequest) -> Future:
        """Validate, key, and enqueue; raises ``queue.Full`` when the
        bounded queue is at depth (backpressure, not silent dropping)."""
        sp, spec, key = self.describe(req)
        now = time.monotonic()
        item = _Item(req=req, program=sp, spec=spec, key=key,
                     future=Future(), submitted=now,
                     deadline=None if req.timeout is None
                     else now + req.timeout)
        self._q.put_nowait(item)
        self.stats.submitted += 1
        return item.future

    def run(self, req: StencilRequest, timeout: float | None = None
            ) -> ServeResult:
        return self.submit(req).result(timeout)

    def map(self, reqs, timeout: float | None = None) -> list:
        futs = [self.submit(r) for r in reqs]
        return [f.result(timeout) for f in futs]

    # ------------------------------------------------------------------
    # worker: micro-batching loop
    # ------------------------------------------------------------------
    def _worker(self) -> None:
        # install the engine's tracer as this thread's ambient tracer so
        # every compile_program / tuner / dataflow emission from the worker
        # lands in the same trace as the serve spans
        with self.tracer.active():
            self._worker_loop()

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            t0 = time.monotonic()
            # micro-batch window: wait briefly for same-bucket company
            while len(batch) < self.max_batch:
                left = self.window_s - (time.monotonic() - t0)
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            groups: dict = {}
            for it in batch:
                groups.setdefault(it.key, []).append(it)
            for key, items in groups.items():
                self._process_group(key, items)

    def _process_group(self, key: str, items: list) -> None:
        now = time.monotonic()
        live = []
        for it in items:
            if it.deadline is not None and now > it.deadline:
                self.stats.timeouts += 1
                it.future.set_exception(
                    TimeoutError(f"request expired after {it.req.timeout}s "
                                 "in queue"))
            else:
                live.append(it)
        if not live:
            return
        tracer = self.tracer
        try:
            if key in self._executors:
                self.stats.exec_hits += len(live)
                if tracer.enabled:
                    tracer.emit(CacheHit(cache="executor", key=key))
                self._executors.move_to_end(key)      # refresh LRU recency
                ex = self._executors[key]
            else:
                self.stats.exec_misses += len(live)
                if tracer.enabled:
                    tracer.emit(CacheMiss(cache="executor", key=key))
                ex = self._build_executor(key, live[0])
                self._executors[key] = ex
                while (self.max_executors is not None
                       and len(self._executors) > self.max_executors):
                    cold, _ = self._executors.popitem(last=False)
                    self.stats.evictions += 1
                    if tracer.enabled:
                        tracer.emit(ExecutorEvicted(
                            key=cold, resident=len(self._executors)))
        except Exception as e:  # compile/planning failure fails the group
            for it in live:
                self.stats.failed += 1
                it.future.set_exception(e)
            return
        for i in range(0, len(live), self.max_batch):
            self._run_batch(ex, live[i:i + self.max_batch])

    # ------------------------------------------------------------------
    # executor build (plan-record reuse lives here)
    # ------------------------------------------------------------------
    def _build_executor(self, key: str, item: _Item) -> _BucketExecutor:
        sp, spec, req = item.program, item.spec, item.req
        tracer = self.tracer
        with tracer.span("serve.build_executor", program=sp.name,
                         bucket="x".join(str(b) for b in item.spec.bucket),
                         steps=req.steps) as bsp:
            plan = carry_write = None
            record_hit = False
            if self.plan_cache is not None:
                dec = read_serve_record(self.plan_cache.lookup(key))
                if dec is not None:
                    plan, carry_write = dec
                    record_hit = True
                    self.stats.plan_hits += 1
                    if tracer.enabled:
                        tracer.emit(CacheHit(cache="serve_record", key=key))
                else:
                    self.stats.plan_misses += 1
                    if tracer.enabled:
                        tracer.emit(CacheMiss(cache="serve_record", key=key))
            update = (None if req.update is None
                      else wrap_update(sp, spec, req.update))
            ex = compile_program(
                sp, spec.bucket, options=CompileOptions(
                    backend=self.backend, plan=plan, jit=False,
                    interpret=self.interpret, dtype=self.dtype,
                    strategy=self.strategy, steps=req.steps, update=update,
                    carry_write=carry_write, schedule=self.schedule,
                    mesh=self.mesh, mesh_axes=self.mesh_axes,
                    time_tile=self.time_tile, plane_tile=self.plane_tile,
                    plan_cache=self.plan_cache))
            self.stats.compiles += 1
            bsp.set(record_hit=record_hit, schedule=ex.plan.schedule)
        cw = ex.time_spec.carry_write if ex.time_spec is not None else "repad"
        if self.plan_cache is not None and not record_hit:
            self.plan_cache.store(
                key, make_serve_record(ex.plan, cw, spec.bucket, req.steps))

        counter = self._traces

        def counted(fields, scalars, coeffs, _raw=ex._fn):
            counter[0] += 1
            return _raw(fields, scalars, coeffs)

        batched = jax.jit(jax.vmap(counted))
        return _BucketExecutor(program=sp, spec=spec, steps=req.steps,
                               batched=batched, unbatched_raw=counted,
                               plan=ex.plan, carry_write=cw)

    # ------------------------------------------------------------------
    # batch execution
    # ------------------------------------------------------------------
    def _run_batch(self, ex: _BucketExecutor, items: list) -> None:
        with self.tracer.span("serve.batch", program=ex.program.name,
                              n=len(items)) as sp:
            self._run_batch_traced(ex, items, sp)

    def _run_batch_traced(self, ex: _BucketExecutor, items: list, sp) -> None:
        t0 = time.monotonic()
        try:
            embedded = [embed_request(ex.program, it.spec, it.req.fields,
                                      it.req.scalars, it.req.coeffs)
                        for it in items]
            n = len(items)
            pad = _pow2_at_least(n)

            def stack(leaves, cast):
                arr = np.stack(leaves)
                if cast and arr.dtype != self._np_dtype:
                    arr = arr.astype(self._np_dtype)
                if pad > n:  # replicate slot 0 into the filler slots
                    arr = np.concatenate(
                        [arr, np.repeat(arr[:1], pad - n, axis=0)])
                return arr

            fields = {f: stack([e[0][f] for e in embedded], True)
                      for f in embedded[0][0]}
            scalars = {s: stack(np.asarray([e[1][s] for e in embedded],
                                           dtype=np.float32), False)
                       for s in embedded[0][1]}
            coeffs = {c: stack([e[2][c] for e in embedded], True)
                      for c in embedded[0][2]}
            try:
                out = ex.batched(fields, scalars, coeffs)
            except Exception:
                if ex.vmap_failed:
                    raise
                ex.fallback_unrolled()
                out = ex.batched(fields, scalars, coeffs)
            out = {k: np.asarray(v) for k, v in out.items()}
            self.stats.batches += 1
            self.stats.batched_requests += n
            self.stats.padded_slots += pad - n
            sp.set(padded=pad - n, vmap_failed=ex.vmap_failed)
            done = time.monotonic()
            self.stats.wall_s += done - t0
            for i, it in enumerate(items):
                res = ServeResult(
                    outputs={k: v[i][it.spec.interior()]
                             for k, v in out.items()},
                    bucket=it.spec, key=it.key,
                    latency_ms=(done - it.submitted) * 1e3, batch_size=n)
                self.stats.completed += 1
                self.stats.record_latency(res.latency_ms)
                it.future.set_result(res)
        except Exception as e:
            for it in items:
                if not it.future.done():
                    self.stats.failed += 1
                    it.future.set_exception(e)
        finally:
            self.stats.traces = self._traces[0]
