"""Shape bucketing for the serving layer — exact execution on padded grids.

The engine compiles each program once per *bucket* (a lane-quantised grid
shape) and runs every request whose grid rounds up to that bucket through
the same compiled executor.  Correctness does not come from masking the
final answer — ghost cells would contaminate the interior one halo per
fused step — but from an invariant maintained jointly by three pieces:

1. **Placement** (:func:`repro.core.schedule.bucket_for`): the real grid
   ``G`` sits at offset ``off = lo`` (the program's low reach) inside a
   bucket ``B >= G + lo + hi``, so no read issued *for an in-domain cell*
   ever crosses the bucket edge.  The compiled program's own boundary
   handling at bucket edges is therefore never observed by real cells.
2. **Embedding** (:func:`embed_field` / :func:`embed_coeff`): on request
   ingress every bucket cell — not just the reach ring — is filled with the
   value the real boundary dictates (0, or the torus wrap of the interior).
3. **Refresh** (:func:`make_refresh`, installed by :func:`wrap_update`):
   after every fused step the out-of-domain cells are rewritten from the
   new interior, restoring the embedding before the next step reads it.

Real grid sizes enter the compiled graph as *traced* scalar arguments
(``_srv_n0`` … appended to ``p.scalars`` by :func:`serving_program`), so
every grid that rounds to the same bucket shares one trace — the engine's
zero-retrace guarantee for warm requests — and the sizes can differ per
batch element under ``vmap``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import boundary as bc
from ..core.ir import Program
from ..core.schedule import BucketSpec, adapt_update, bucket_for  # noqa: F401

SIZE_SCALAR_PREFIX = "_srv_n"


def size_scalar_names(ndim: int) -> list:
    return [f"{SIZE_SCALAR_PREFIX}{a}" for a in range(ndim)]


def serving_program(p: Program) -> Program:
    """A copy of ``p`` with per-axis grid-size scalars appended.

    Appending (never inserting) keeps existing scalar indices stable for
    the Pallas backend's packed scalar vector.  Idempotent: a program that
    already carries the size scalars is returned unchanged.
    """
    names = size_scalar_names(p.ndim)
    if all(n in p.scalars for n in names):
        return p
    clash = [n for n in p.scalars if n.startswith(SIZE_SCALAR_PREFIX)]
    if clash:
        raise ValueError(f"program scalars {clash} collide with the "
                         f"serving size-scalar prefix {SIZE_SCALAR_PREFIX!r}")
    sp = Program(name=p.name, ndim=p.ndim, fields=dict(p.fields),
                 scalars=list(p.scalars) + names, ops=list(p.ops),
                 coeffs=dict(p.coeffs))
    sp.validate()
    return sp


def size_scalars(spec: BucketSpec) -> dict:
    return {f"{SIZE_SCALAR_PREFIX}{a}": float(g)
            for a, g in enumerate(spec.grid)}


# --------------------------------------------------------------------------
# Host-side embed / crop (request ingress and egress)
# --------------------------------------------------------------------------


def embed_field(x, spec: BucketSpec, boundary: str) -> np.ndarray:
    """Place a real-grid array into its bucket, filling every out-of-domain
    cell per the field's boundary (zeros, or the torus wrap of ``x``)."""
    x = np.asarray(x)
    if tuple(x.shape) != tuple(spec.grid):
        raise ValueError(f"field shape {x.shape} != request grid {spec.grid}")
    if boundary == "periodic":
        idxs = [(np.arange(b) - o) % g
                for g, b, o in zip(spec.grid, spec.bucket, spec.offset)]
        return x[np.ix_(*idxs)]
    out = np.zeros(spec.bucket, dtype=x.dtype)
    out[spec.interior()] = x
    return out


def embed_coeff(c, axis: int, spec: BucketSpec, mode: str) -> np.ndarray:
    """Extend a per-axis coefficient array to bucket length.

    ``mode`` must match :func:`repro.core.boundary.coeff_mode` for the
    program so the embedded values agree with what the exact-grid compile
    would read through its shifted-coefficient path.
    """
    c = np.asarray(c)
    g, b, o = spec.grid[axis], spec.bucket[axis], spec.offset[axis]
    if c.shape != (g,):
        raise ValueError(f"coeff shape {c.shape} != ({g},) on axis {axis}")
    if mode == "periodic":
        return c[(np.arange(b) - o) % g]
    out = np.zeros(b, dtype=c.dtype)
    out[o:o + g] = c
    return out


def crop(x, spec: BucketSpec):
    """Slice the real-grid interior back out of a bucket-shaped array."""
    return x[spec.interior()]


def embed_request(p: Program, spec: BucketSpec, fields, scalars=None,
                  coeffs=None):
    """Embed one request's arrays and attach the traced size scalars.

    Returns (fields, scalars, coeffs) dicts shaped for the bucket compile.
    """
    bnd = p.boundaries()
    cmode = bc.coeff_mode(p)
    efields = {f: embed_field(x, spec, bnd[f]) for f, x in fields.items()}
    escalars = dict(scalars or {})
    escalars.update(size_scalars(spec))
    ecoeffs = {c: embed_coeff(x, p.coeffs[c], spec, cmode)
               for c, x in (coeffs or {}).items()}
    return efields, escalars, ecoeffs


# --------------------------------------------------------------------------
# Device-side refresh (re-establish the embedding after each fused step)
# --------------------------------------------------------------------------


def make_refresh(p: Program, spec: BucketSpec):
    """Build ``refresh(fields, scalars) -> fields`` rewriting out-of-domain
    bucket cells from the (possibly traced, per-request) grid sizes.

    Periodic fields gather ``x[off + (i - off) mod n]`` along each axis;
    zero fields mask cells outside ``[off, off + n)``.  Sizes come from the
    ``_srv_n*`` scalars so the gather/mask shapes are static (bucket-sized)
    while the wrap length is traced — one trace covers every grid in the
    bucket, and ``vmap`` batches requests with different sizes.

    Under ``shard_map`` the refresh sees *local* shards; ``origin`` (the
    shard's global offset vector) shifts the zero-boundary masks into
    global coordinates.  The periodic gather is a whole-axis permutation
    with no shard-local form, so periodic fields reject a non-None origin.
    """
    bnd = p.boundaries()
    names = size_scalar_names(p.ndim)
    offs = tuple(int(o) for o in spec.offset)
    bucket = tuple(int(b) for b in spec.bucket)

    def refresh(fields, scalars, origin=None):
        ns = [jnp.asarray(scalars[nm]).astype(jnp.int32) for nm in names]
        out = {}
        for f, x in fields.items():
            if bnd.get(f) == "periodic":
                if origin is not None:
                    raise NotImplementedError(
                        f"periodic field {f!r}: the bucket refresh is a "
                        "global torus gather with no shard-local form; "
                        "serve periodic fused loops unsharded")
                for a in range(p.ndim):
                    idx = offs[a] + (jnp.arange(bucket[a]) - offs[a]) % ns[a]
                    x = jnp.take(x, idx, axis=a)
            else:
                for a in range(p.ndim):
                    i = jnp.arange(x.shape[a])
                    if origin is not None:
                        i = i + origin[a]
                    inb = (i >= offs[a]) & (i < offs[a] + ns[a])
                    shape = [1] * p.ndim
                    shape[a] = x.shape[a]
                    x = jnp.where(inb.reshape(shape), x, 0)
            out[f] = x
        return out

    return refresh


def wrap_update(p: Program, spec: BucketSpec, update, trace_counter=None):
    """Wrap a user update rule for bucketed fused-loop execution.

    The wrapped rule runs the user's update on the bucket-shaped fields,
    then refreshes the out-of-domain cells so step ``t+1`` reads the same
    embedding step ``t`` did.  ``trace_counter`` (a one-element list) is
    bumped at trace time — the engine's re-trace instrumentation.
    """
    user = adapt_update(update)
    refresh = make_refresh(p, spec)

    def wrapped(fields, outputs, scalars, origin=None):
        if trace_counter is not None:
            trace_counter[0] += 1
        new = dict(fields)
        new.update(user(fields, outputs, scalars))
        return refresh(new, scalars, origin)

    wrapped._takes_scalars = True
    # sharded time loops feed the shard's global offset so the refresh
    # masks in global coordinates
    wrapped._takes_origin = True
    # the refresh gathers across whole bucket axes — there is no plane-local
    # form, so stream compiles must not chain this update into the kernel
    wrapped._plane_local = False
    return wrapped
