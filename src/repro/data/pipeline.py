"""Data pipeline: deterministic synthetic streams + memmap token corpora.

Both sources are *step-addressable* (``batch_at(step)``): any host can
reproduce any global step's batch, which is what checkpoint/restart and
elastic re-sharding need — after a failure the resumed run consumes exactly
the batches it would have, with no data-loader state to persist.

Per-host sharding: a host materialises only its slice of the global batch
(``host_slice``), so the loader scales to thousands of workers.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab: int
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.n_hosts:
            raise ValueError("global batch must divide across hosts")
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Seeded Zipfian token stream with local n-gram structure: enough
    signal that a 100M model's loss visibly falls within a few hundred
    steps (quickstart/train examples), fully deterministic per (seed, step).
    """

    def __init__(self, spec: BatchSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed
        # Zipf weights over the vocab
        ranks = np.arange(1, spec.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks ** 1.1)
        self._probs /= self._probs.sum()

    def batch_at(self, step: int) -> dict:
        spec = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, spec.host_id]))
        b, s = spec.host_batch, spec.seq_len
        toks = rng.choice(spec.vocab, size=(b, s + 1), p=self._probs)
        # inject learnable bigram structure: even positions copy forward
        toks[:, 2::2] = (toks[:, 1:-1:2] * 31 + 7) % spec.vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapCorpus:
    """Flat binary token file (uint16/uint32) sampled in fixed windows.

    ``batch_at(step)`` draws deterministic offsets, so the corpus reader has
    the same restartability contract as the synthetic stream.
    """

    def __init__(self, path: str, spec: BatchSpec, dtype="uint16", seed: int = 0):
        self.spec = spec
        self.seed = seed
        self.data = np.memmap(path, dtype=dtype, mode="r")
        if len(self.data) < spec.seq_len + 1:
            raise ValueError("corpus shorter than one sample")

    def batch_at(self, step: int) -> dict:
        spec = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, spec.host_id]))
        b, s = spec.host_batch, spec.seq_len
        starts = rng.integers(0, len(self.data) - s - 1, size=b)
        toks = np.stack([self.data[st:st + s + 1] for st in starts])
        toks = toks.astype(np.int32) % spec.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batches(source, start_step: int = 0):
    """Resume-aware iterator: yields (step, batch) from ``start_step``."""
    step = start_step
    while True:
        yield step, source.batch_at(step)
        step += 1


def write_corpus(path: str, tokens: np.ndarray, dtype="uint16"):
    np.asarray(tokens, dtype=dtype).tofile(path)
