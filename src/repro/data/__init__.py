from .pipeline import (SyntheticLM, MemmapCorpus, make_batches, BatchSpec)
