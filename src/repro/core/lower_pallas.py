"""Pallas backend orchestrator: Program + DataflowPlan -> executable.

Runs the plan's fuse groups in order.  Fields crossing a group boundary are
materialised in HBM — the TPU equivalent of the paper's inter-stage streams —
and re-padded for the consuming group's windows.  Inside a group everything
flows through the generated kernel's VMEM windows (see kernels/stencil3d.py).
"""

from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from ..kernels.stencil3d import build_group_call
from . import boundary as bc
from .ir import Program
from .schedule import DataflowPlan, TimeLoopSpec, adapt_update

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}


def _pad_coeffs(p: Program, calls, coeffs, dtype):
    """Per-call padded coefficient windows ('small data', paper step 8)."""
    cmode = bc.coeff_mode(p)
    out = []
    for call in calls:
        pc = {}
        for c in call.group_coeffs:
            ax = call.coeff_axis[c]
            pc[c] = bc.pad_coeff(jnp.asarray(coeffs[c], dtype=dtype),
                                 call.pad_lo[ax], call.pad_hi[ax], cmode)
        out.append(pc)
    return out


def _run_groups(p: Program, calls, svec, pc_per_call, resolve_input,
                origin=None):
    """Run the fuse groups in order, materialising inter-group fields.

    ``resolve_input(call, f, env) -> (array, actual_pad | None)`` supplies
    each group input: either freshly padded to the call's window geometry
    (pad None) or an oversized persistent buffer with its actual padding,
    which the kernel slices its window out of via ``input_pad``.
    ``origin`` is the shard's global offset under a mesh (None locally).
    """
    env: dict = {}
    outputs: dict = {}
    for call, pc in zip(calls, pc_per_call):
        padded, ipad = {}, {}
        for f in call.group_inputs:
            padded[f], actual = resolve_input(call, f, env)
            if actual is not None:
                ipad[f] = actual
        res = call(padded, svec, pc, input_pad=ipad or None, origin=origin)
        env.update(res)
        for f, v in res.items():
            if p.fields[f].role.value == "output":
                outputs[f] = v
    return outputs


def _scalar_vec(p: Program, scalars):
    return (jnp.asarray([scalars[s] for s in p.scalars], dtype=jnp.float32)
            if p.scalars else None)


def lower(p: Program, plan: DataflowPlan, grid_shape):
    """Return fn(fields, scalars) -> dict of output arrays."""
    dtype = _DTYPES[plan.dtype]
    grid_shape = tuple(int(g) for g in grid_shape)
    calls = [build_group_call(p, grp, plan.block, grid_shape, dtype=dtype,
                              interpret=plan.interpret)
             for grp in plan.groups]
    return lower_from_calls(p, dtype, calls)


def lower_from_calls(p: Program, dtype, calls):
    """Single-step orchestrator over prebuilt kernel calls (shared by the
    block schedule above and the stream schedule in lower_stream.py — any
    call exposing the build_group_call geometry attributes works)."""

    def run(fields: Mapping[str, jnp.ndarray],
            scalars: Mapping[str, jnp.ndarray] | None = None,
            coeffs: Mapping[str, jnp.ndarray] | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        ext = {k: jnp.asarray(v, dtype=dtype) for k, v in fields.items()}
        bnd = p.boundaries()

        def resolve(call, f, env):
            x = env[f] if f in env else ext[f]
            return bc.pad_field(x, call.halo_lo, call.halo_hi, bnd[f],
                                align_hi=call.align_hi), None

        return _run_groups(p, calls, _scalar_vec(p, scalars),
                           _pad_coeffs(p, calls, coeffs, dtype), resolve)

    return run


def lower_time_loop(p: Program, plan: DataflowPlan, grid_shape,
                    spec: TimeLoopSpec, update):
    """Return fn(fields, scalars, coeffs) -> final fields after ``spec.steps``
    fused iterations — one compiled program, no host round trips.

    The carry of a ``lax.fori_loop`` holds one *pre-padded* persistent buffer
    per program input field, sized by ``spec.field_pad`` so every consuming
    fuse group can slice its window geometry straight out of it (the kernel's
    ``input_pad`` path).  Halo slabs follow each field's boundary: zero
    slabs never change, so writing the back buffer each step touches only
    the interior — either scattered in place (``carry_write="inplace"``) or
    rebuilt as one fused interior-plus-constant-halo write (``"repad"``,
    the default; see :class:`TimeLoopSpec`); periodic slabs are rebuilt
    from the new interior (the wraparound values change with it).  XLA
    donates the loop carry,
    giving the front/back buffer swap ``spec.double_buffer`` assigns.
    Coefficients are loop-invariant and padded once, outside the loop.
    """
    dtype = _DTYPES[plan.dtype]
    grid_shape = tuple(int(g) for g in grid_shape)
    calls = [build_group_call(p, grp, plan.block, grid_shape, dtype=dtype,
                              interpret=plan.interpret)
             for grp in plan.groups]
    return time_loop_from_calls(p, dtype, grid_shape, spec, update, calls)


def time_loop_from_calls(p: Program, dtype, grid_shape, spec: TimeLoopSpec,
                         update, calls, chain: int = 1, epilogue=None):
    """Fused-loop orchestrator over prebuilt kernel calls (shared with the
    stream schedule, whose carries have no alignment slab).

    ``chain`` is how many time steps one pass over ``calls`` advances: 1
    for plain kernels (stencil outputs + one update here, per iteration),
    T for a temporally-blocked stream chain, which applies all T updates
    in-kernel and *returns the new fields* (``call.returns_fields``) — the
    loop body then only writes them back into the carry.  The loop runs
    ``spec.steps // chain`` iterations, and ``epilogue`` — a second call
    list advancing ``spec.steps % chain`` steps — runs once after it,
    slicing its (shallower) windows out of the same carry via
    ``input_pad``.
    """
    update = adapt_update(update)
    ndim = p.ndim
    fpad = spec.field_pad
    bnd = p.boundaries()
    align = spec.align_hi or (0,) * ndim
    chain = max(1, int(chain))
    outer = int(spec.steps) // chain
    if int(spec.steps) % chain and epilogue is None and chain > 1:
        raise ValueError(
            f"steps={spec.steps} is not a multiple of the chain depth "
            f"{chain} and no remainder epilogue was provided")
    interior = {f: tuple(slice(int(fpad[f][a, 0]),
                               int(fpad[f][a, 0]) + grid_shape[a])
                         for a in range(ndim))
                for f in spec.persistent}

    def refill(f, x):
        # halo slabs per the field's boundary; the lane-alignment slab
        # (inside fpad[:, 1]) is always zero — never read in-domain
        return bc.pad_field(x, fpad[f][:, 0],
                            [int(fpad[f][a, 1]) - int(align[a])
                             for a in range(ndim)],
                            bnd[f], align_hi=align)

    def run(fields: Mapping, scalars: Mapping | None = None,
            coeffs: Mapping | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        svec = _scalar_vec(p, scalars)
        # coefficients never change across steps: pad per consuming group
        # once, before the loop ("small data" stays resident)
        pc_per_call = _pad_coeffs(p, calls, coeffs, dtype)
        pc_epilogue = (_pad_coeffs(p, epilogue, coeffs, dtype)
                       if epilogue is not None else None)
        # pad the persistent carry buffers exactly once
        carry = {f: refill(f, jnp.asarray(fields[f], dtype=dtype))
                 for f in spec.persistent}

        def advance(carry, calls_, pc_):
            def resolve(call, f, env):
                if f in carry:              # persistent: window from carry
                    return carry[f], fpad[f]
                return bc.pad_field(env[f], call.halo_lo, call.halo_hi,
                                    bnd[f], align_hi=call.align_hi), None

            if getattr(calls_[0], "returns_fields", False):
                # temporally-blocked chain: one call advances every field
                # by its full chain depth, updates included
                call = calls_[0]
                padded = {f: carry[f] for f in call.group_inputs}
                new = call(padded, svec, pc_[0],
                           input_pad={f: fpad[f] for f in call.group_inputs})
            else:
                outputs = _run_groups(p, calls_, svec, pc_, resolve)
                cur = {f: carry[f][interior[f]] for f in spec.persistent}
                new = dict(cur)
                new.update(update(cur, outputs, scalars))
            out = {}
            for f in spec.persistent:
                if spec.carry_write == "inplace" and bnd[f] == "zero":
                    # zero halos never change: scatter the interior only
                    out[f] = carry[f].at[interior[f]].set(
                        jnp.asarray(new[f], dtype=dtype))
                else:
                    # one fused interior write + constant (zero) or
                    # refreshed (wraparound) halo slabs — no carry RMW
                    out[f] = refill(f, jnp.asarray(new[f], dtype=dtype))
            return out

        def body(_, carry):
            return advance(carry, calls, pc_per_call)

        carry = jax.lax.fori_loop(0, outer, body, carry)
        if epilogue is not None and int(spec.steps) % chain:
            carry = advance(carry, epilogue, pc_epilogue)
        return {f: carry[f][interior[f]] for f in spec.persistent}

    return run
