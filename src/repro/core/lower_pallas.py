"""Pallas backend orchestrator: Program + DataflowPlan -> executable.

Runs the plan's fuse groups in order.  Fields crossing a group boundary are
materialised in HBM — the TPU equivalent of the paper's inter-stage streams —
and re-padded for the consuming group's windows.  Inside a group everything
flows through the generated kernel's VMEM windows (see kernels/stencil3d.py).
"""

from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.stencil3d import build_group_call
from .ir import Program
from .schedule import DataflowPlan

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}


def lower(p: Program, plan: DataflowPlan, grid_shape):
    """Return fn(fields, scalars) -> dict of output arrays."""
    dtype = _DTYPES[plan.dtype]
    grid_shape = tuple(int(g) for g in grid_shape)
    calls = [build_group_call(p, grp, plan.block, grid_shape, dtype=dtype,
                              interpret=plan.interpret)
             for grp in plan.groups]

    def run(fields: Mapping[str, jnp.ndarray],
            scalars: Mapping[str, jnp.ndarray] | None = None,
            coeffs: Mapping[str, jnp.ndarray] | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        svec = (jnp.asarray([scalars[s] for s in p.scalars], dtype=jnp.float32)
                if p.scalars else None)
        env = {k: jnp.asarray(v, dtype=dtype) for k, v in fields.items()}
        outputs: dict = {}
        for call in calls:
            padded = {}
            for f in call.group_inputs:
                pads = tuple((call.pad_lo[a], call.pad_hi[a])
                             for a in range(p.ndim))
                padded[f] = jnp.pad(env[f], pads)
            pc = {}
            for c in call.group_coeffs:
                ax = call.coeff_axis[c]
                pc[c] = jnp.pad(jnp.asarray(coeffs[c], dtype=dtype),
                                (call.pad_lo[ax], call.pad_hi[ax]))
            res = call(padded, svec, pc)
            env.update(res)
            for f, v in res.items():
                if p.fields[f].role.value == "output":
                    outputs[f] = v
        return outputs

    return run
