"""Distributed stencil execution: domain decomposition + halo exchange.

The TPU-cluster analogue of the paper's step 9 ("one AXI bundle / HBM bank
per field"): every chip owns a contiguous sub-domain in its own HBM, and the
inter-bank traffic becomes ``lax.ppermute`` halo exchange over ICI.

Structure inside ``shard_map``:

    for each fuse group (dataflow stage):
        for each stage input:  halo-exchange + pad  (axis-by-axis, so the
                               slab sent along axis k carries the halos
                               already attached for axes < k -> corners are
                               correct for diagonal offsets)
        run the generated Pallas group kernel on the local padded block,
        passing the shard origin so the global-domain mask is exact
        stage outputs feed later stages

Edges are zero-filled (non-periodic): ``ppermute`` leaves non-receiving
shards with zeros, which *is* the IR's zero-halo convention — no special
boundary code.  XLA schedules the per-axis permutes of different fields
independently, so halo traffic overlaps with the Pallas compute of earlier
groups (dataflow concurrency at cluster scale).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # public since jax 0.6; experimental before that
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from ..kernels.stencil3d import build_group_call
from .ir import FieldRole, Program
from .schedule import DataflowPlan, auto_plan

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}


def _axis_size(mesh: Mesh, name) -> int:
    return 1 if name is None else int(mesh.shape[name])


def halo_exchange_pad(x: jnp.ndarray, lo: Sequence[int], hi: Sequence[int],
                      align_hi: Sequence[int], mesh_axes: Sequence,
                      axis_sizes: Mapping | None = None) -> jnp.ndarray:
    """Pad a local block with neighbour halos (sharded axes) or zeros.

    ``axis_sizes`` maps mesh-axis name -> size (static, from the mesh); the
    trace environment has no portable size query across jax versions."""
    ndim = x.ndim
    axis_sizes = axis_sizes or {}
    for ax in range(ndim):
        l, h, al = int(lo[ax]), int(hi[ax]), int(align_hi[ax])
        a = mesh_axes[ax] if ax < len(mesh_axes) else None
        if l == 0 and h == 0 and al == 0:
            continue
        n = 1 if a is None else int(axis_sizes[a])
        pieces = []
        if l > 0:
            if a is not None and n > 1:
                src = jax.lax.slice_in_dim(x, x.shape[ax] - l, x.shape[ax], axis=ax)
                pieces.append(jax.lax.ppermute(
                    src, a, [(i, i + 1) for i in range(n - 1)]))
            else:
                shp = list(x.shape); shp[ax] = l
                pieces.append(jnp.zeros(shp, x.dtype))
        pieces.append(x)
        if h > 0:
            if a is not None and n > 1:
                src = jax.lax.slice_in_dim(x, 0, h, axis=ax)
                pieces.append(jax.lax.ppermute(
                    src, a, [(i + 1, i) for i in range(n - 1)]))
            else:
                shp = list(x.shape); shp[ax] = h
                pieces.append(jnp.zeros(shp, x.dtype))
        if al > 0:
            shp = list(x.shape); shp[ax] = al
            pieces.append(jnp.zeros(shp, x.dtype))
        x = jnp.concatenate(pieces, axis=ax)
    return x


def make_sharded_executor(p: Program, global_grid, mesh: Mesh,
                          mesh_axes: Sequence, *,
                          plan: DataflowPlan | None = None,
                          interpret: bool = True, dtype: str = "float32"):
    """Build fn(fields, scalars, coeffs) running the program SPMD over ``mesh``.

    ``mesh_axes[ax]`` names the mesh axis sharding grid axis ``ax`` (or None).
    Fields are sharded ``P(*mesh_axes)``; coefficient arrays are replicated
    and sliced locally ('small data' lives on every chip, paper step 8).
    """
    global_grid = tuple(int(g) for g in global_grid)
    ndim = p.ndim
    mesh_axes = tuple(mesh_axes)[:ndim] + (None,) * (ndim - len(mesh_axes))
    local_grid = []
    for ax in range(ndim):
        n = _axis_size(mesh, mesh_axes[ax])
        if global_grid[ax] % n:
            raise ValueError(f"grid axis {ax} ({global_grid[ax]}) not divisible "
                             f"by mesh axis {mesh_axes[ax]!r} ({n})")
        local_grid.append(global_grid[ax] // n)
    local_grid = tuple(local_grid)

    if plan is None:
        plan = auto_plan(p, local_grid, interpret=interpret, dtype=dtype)
    jdtype = _DTYPES[plan.dtype]

    calls = [build_group_call(p, grp, plan.block, local_grid, dtype=jdtype,
                              interpret=plan.interpret,
                              global_extent=global_grid)
             for grp in plan.groups]

    # coeffs: replicate globally, pre-padded so any shard can slice its piece
    coeff_lo = {c: 0 for c in p.coeffs}
    coeff_hi = {c: 0 for c in p.coeffs}
    for call in calls:
        for c in call.group_coeffs:
            ax = call.coeff_axis[c]
            coeff_lo[c] = max(coeff_lo[c], call.pad_lo[ax])
            coeff_hi[c] = max(coeff_hi[c], call.pad_hi[ax])

    field_spec = P(*mesh_axes)
    out_names = p.output_fields()
    n_scalars = len(p.scalars)

    def local_fn(svec, fields, coeffs):
        origin = []
        for ax in range(ndim):
            idx = (jax.lax.axis_index(mesh_axes[ax])
                   if mesh_axes[ax] is not None else 0)
            origin.append(jnp.int32(idx * local_grid[ax]))
        origin = jnp.stack(origin)

        env = dict(fields)
        outputs = {}
        for call in calls:
            padded = {f: halo_exchange_pad(env[f], call.halo_lo, call.halo_hi,
                                           call.align_hi, mesh_axes,
                                           dict(mesh.shape))
                      for f in call.group_inputs}
            pc = {}
            for c in call.group_coeffs:
                ax = call.coeff_axis[c]
                start = origin[ax] + coeff_lo[c] - call.pad_lo[ax]
                pc[c] = jax.lax.dynamic_slice(
                    coeffs[c], (start,),
                    (local_grid[ax] + call.pad_lo[ax] + call.pad_hi[ax],))
            res = call(padded, svec, pc, origin=origin)
            env.update(res)
            for f, v in res.items():
                if p.fields[f].role == FieldRole.OUTPUT:
                    outputs[f] = v
        return tuple(outputs[f] for f in out_names)

    in_specs = (P(),
                {f: field_spec for f in p.input_fields()},
                {c: P() for c in p.coeffs})
    out_specs = tuple(field_spec for _ in out_names)
    try:
        smapped = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except TypeError:  # jax 0.4.x spells the replication check check_rep
        smapped = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)

    def run(fields: Mapping, scalars: Mapping | None = None,
            coeffs: Mapping | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        svec = (jnp.asarray([scalars[s] for s in p.scalars], dtype=jnp.float32)
                if n_scalars else jnp.zeros((1,), jnp.float32))
        fdict = {k: jnp.asarray(fields[k], dtype=jdtype)
                 for k in p.input_fields()}
        cdict = {c: jnp.pad(jnp.asarray(coeffs[c], dtype=jdtype),
                            (coeff_lo[c], coeff_hi[c]))
                 for c in p.coeffs}
        res = smapped(svec, fdict, cdict)
        return dict(zip(out_names, res))

    run.local_grid = local_grid
    run.plan = plan
    run.mesh_axes = mesh_axes
    run.field_spec = field_spec
    return run
