"""Distributed stencil execution: domain decomposition + halo exchange.

The TPU-cluster analogue of the paper's step 9 ("one AXI bundle / HBM bank
per field"): every chip owns a contiguous sub-domain in its own HBM, and the
inter-bank traffic becomes ``lax.ppermute`` halo exchange over ICI.

This module is the *sharded lowering* consumed by
:func:`repro.core.pipeline.compile_program` — the same planner output
(:class:`DataflowPlan` + :class:`ShardSpec` + :class:`TimeLoopSpec`) that
drives the local backends drives the SPMD ones:

* :func:`lower_sharded` — one program step under ``shard_map``.  Per fuse
  group, every group input is halo-exchanged axis-by-axis (the slab sent
  along axis k carries the halos already attached for axes < k, so corners
  are correct for diagonal offsets), then the group runs on the local
  padded block with the shard origin so the global-domain mask is exact.

* :func:`lower_sharded_time_loop` — the whole time loop in one dispatch:
  a ``lax.fori_loop`` *inside* ``shard_map`` whose carry holds one
  pre-padded local buffer per persistent field.  Each step refreshes the
  halo slabs by ``ppermute`` straight from the carry (no host round trip),
  runs the fuse groups against the refreshed buffers (the kernels slice
  their windows via ``input_pad``), and writes the new interiors back.
  One exchange per field per step serves every consuming group, because
  the carry is padded to the worst group's halo (``TimeLoopSpec.field_pad``;
  ``ShardSpec.field_halo`` records the same per-field halos for the
  plan-time single-hop validation).

Boundaries follow each field's IR declaration (:mod:`repro.core.boundary`):
``"zero"`` uses partial ``ppermute`` rings whose unreceiving edge shards
stay zero-filled — the zero-halo convention with no special code — while
``"periodic"`` closes the ring (and wraps locally on unsharded axes), so
the same program runs a torus across any mesh.  XLA schedules the per-axis
permutes of different fields independently, so halo traffic overlaps with
the compute of earlier groups (dataflow concurrency at cluster scale).

All three backends lower here: ``pallas`` runs the generated group kernels
on local blocks; the jnp backends route temp accesses through
:func:`lower_jnp.lower`'s ``shift_fn`` hook (ppermute shifts) and slice
replicated coefficient arrays at the shard origin via ``coeff_fn``.

:func:`make_sharded_executor` — the original standalone entry point — is
deprecated; it now simply forwards to ``compile_program(..., mesh=...)``.
"""

from __future__ import annotations

import warnings
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # public since jax 0.6; experimental before that
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from ..kernels.stencil3d import build_group_call
from ..obs.trace import current_tracer
from . import boundary as bc
from .dataflow import STREAM_AXIS, lower_to_dataflow
from .ir import Program
from .lower_jnp import lower as lower_jnp_step
from .lower_pallas import _pad_coeffs, _run_groups
from .lower_stream import build_stream_call
from .schedule import DataflowPlan, ShardSpec, TimeLoopSpec, adapt_update

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}


def _exchange_axis(x: jnp.ndarray, ax: int, lo: int, hi: int, align: int,
                   axis_name, n: int, periodic: bool) -> jnp.ndarray:
    """Pad ``x`` along one axis with neighbour halos, wrap, or zeros.

    Sharded axes (``axis_name`` with ``n > 1``) fetch the slabs by
    ``ppermute`` (ring closed iff periodic); unsharded axes wrap locally
    (periodic) or zero-fill.  ``align`` appends a zero alignment slab.
    """
    lo, hi, align = int(lo), int(hi), int(align)
    if lo == 0 and hi == 0 and align == 0:
        return x
    sharded = axis_name is not None and n > 1
    size = x.shape[ax]
    pieces = []
    if lo > 0:
        if sharded:
            src = jax.lax.slice_in_dim(x, size - lo, size, axis=ax)
            pieces.append(jax.lax.ppermute(
                src, axis_name, bc.ring_perms(n, +1, periodic)))
        elif periodic:
            pieces.append(jax.lax.slice_in_dim(x, size - lo, size, axis=ax))
        else:
            shp = list(x.shape); shp[ax] = lo
            pieces.append(jnp.zeros(shp, x.dtype))
    pieces.append(x)
    if hi > 0:
        if sharded:
            src = jax.lax.slice_in_dim(x, 0, hi, axis=ax)
            pieces.append(jax.lax.ppermute(
                src, axis_name, bc.ring_perms(n, -1, periodic)))
        elif periodic:
            pieces.append(jax.lax.slice_in_dim(x, 0, hi, axis=ax))
        else:
            shp = list(x.shape); shp[ax] = hi
            pieces.append(jnp.zeros(shp, x.dtype))
    if align > 0:
        shp = list(x.shape); shp[ax] = align
        pieces.append(jnp.zeros(shp, x.dtype))
    return jnp.concatenate(pieces, axis=ax) if len(pieces) > 1 else pieces[0]


def halo_exchange_pad(x: jnp.ndarray, lo: Sequence[int], hi: Sequence[int],
                      align_hi: Sequence[int], mesh_axes: Sequence,
                      axis_sizes: Mapping | None = None,
                      periodic: bool = False) -> jnp.ndarray:
    """Pad a local block with neighbour halos (sharded axes), wraparound
    (periodic unsharded axes), or zeros.

    ``axis_sizes`` maps mesh-axis name -> size (static, from the mesh); the
    trace environment has no portable size query across jax versions."""
    axis_sizes = axis_sizes or {}
    for ax in range(x.ndim):
        a = mesh_axes[ax] if ax < len(mesh_axes) else None
        n = 1 if a is None else int(axis_sizes[a])
        al = int(align_hi[ax]) if ax < len(align_hi) else 0
        x = _exchange_axis(x, ax, int(lo[ax]), int(hi[ax]), al, a, n, periodic)
    return x


# --------------------------------------------------------------------------
# SPMD plumbing shared by the single-step and fused-loop lowerings
# --------------------------------------------------------------------------

def _smap(fn, mesh: Mesh, in_specs, out_specs):
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:  # jax 0.4.x spells the replication check check_rep
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def _origin_inputs(shard: ShardSpec):
    """(host arrays, in_specs) feeding each shard its global grid offset.

    One 1-D int32 array per grid axis, sharded along that axis's mesh
    dimension, so every shard reads its own offset as element 0 of its
    slice.  This deliberately avoids ``lax.axis_index``: its partition-id
    lowering is rejected by XLA:CPU's SPMD partitioner when it feeds a
    ``fori_loop`` body, and a data-fed origin also constant-folds a
    degenerate 1x..x1 mesh to the exact single-device graph."""
    arrs, specs = [], []
    for ax, name in enumerate(shard.mesh_axes):
        n = shard.axis_size(ax)
        arrs.append(jnp.arange(n, dtype=jnp.int32) * shard.local_grid[ax])
        specs.append(P(name))
    return tuple(arrs), tuple(specs)


def _origin(shard: ShardSpec, origs) -> jnp.ndarray:
    """The shard's global offset vector, from its _origin_inputs slices.

    Unsharded (size-1) axes contribute a *static* zero so a degenerate
    1x..x1 mesh constant-folds to the exact single-device graph."""
    return jnp.stack([origs[ax][0] if shard.axis_size(ax) > 1
                      else jnp.int32(0)
                      for ax in range(len(shard.mesh_axes))])


def _degenerate(shard: ShardSpec) -> bool:
    """True when no grid axis is actually sharded (a 1x..x1 mesh): the
    distributed access hooks then degrade to the plain local paths, so the
    compiled graph — and its floating-point rounding — is bit-identical to
    the single-device lowering."""
    return all(shard.axis_size(ax) == 1 for ax in range(len(shard.mesh_axes)))


def _coeff_reach(p: Program, shard: ShardSpec) -> dict:
    """coeff name -> (lo, hi) extension covering every CoeffRef offset."""
    reach = {c: [0, 0] for c in p.coeffs}
    if _degenerate(shard):
        return reach       # no origin slicing: coeffs pass through raw
    for op in p.ops:
        for c in op.coeff_refs():
            reach[c.coeff][0] = max(reach[c.coeff][0], -int(c.offset))
            reach[c.coeff][1] = max(reach[c.coeff][1], int(c.offset))
    return reach


def _jnp_step_hooks(p: Program, shard: ShardSpec, origin, reach: dict):
    """(shift_fn, coeff_fn) routing jnp-backend accesses across the mesh.

    Both are None on a degenerate mesh — :func:`lower_jnp.lower` then uses
    its local boundary-aware defaults, keeping the graph bit-identical to
    the single-device compile."""
    if _degenerate(shard):
        return None, None
    ndim = p.ndim

    def shift(x, offset, kind):
        for ax in range(ndim):
            o = int(offset[ax])
            if o == 0:
                continue
            n_loc = shard.local_grid[ax]
            if abs(o) > n_loc:
                raise ValueError(
                    f"offset {o} on axis {ax} exceeds the local extent "
                    f"{n_loc} (halo exchange is single-hop)")
            lo, hi = max(0, -o), max(0, o)
            xp = _exchange_axis(x, ax, lo, hi, 0, shard.mesh_axes[ax],
                                shard.axis_size(ax), kind == "periodic")
            x = jax.lax.slice_in_dim(xp, lo + o, lo + o + n_loc, axis=ax)
        return x

    def coeff(cref, coeffs):
        # coeffs arrive replicated and pre-extended by ``reach`` on the
        # host; the shard slices its local window at the global origin
        ax = p.coeffs[cref.coeff]
        start = origin[ax] + reach[cref.coeff][0] + int(cref.offset)
        v = jax.lax.dynamic_slice(coeffs[cref.coeff], (start,),
                                  (shard.local_grid[ax],))
        shape = [1] * ndim
        shape[ax] = shard.local_grid[ax]
        return v.reshape(shape)

    return shift, coeff


def _in_specs(p: Program, shard: ShardSpec, origin_specs, scal_spec) -> tuple:
    """shard_map input specs: (scalars, fields, coeffs, origin arrays)."""
    field_spec = P(*shard.mesh_axes)
    return (scal_spec,
            {f: field_spec for f in p.input_fields()},
            {c: P() for c in p.coeffs},
            origin_specs)


def _scalar_io(p: Program, backend: str):
    """(replicated spec, packer) for the runtime scalars.

    The pallas kernels take one packed SMEM vector; the jnp lowerings take
    the plain name->value dict — keeping each backend's scalar plumbing
    identical to its local lowering, so a degenerate mesh bit-matches."""
    if backend == "pallas":
        def pack(scalars):
            return (jnp.asarray([scalars[s] for s in p.scalars],
                                dtype=jnp.float32)
                    if p.scalars else jnp.zeros((1,), jnp.float32))
        return P(), pack

    def pack(scalars):
        return {s: scalars[s] for s in p.scalars}
    return {s: P() for s in p.scalars}, pack


def _host_coeffs(p: Program, coeffs: Mapping, jdtype, reach: dict) -> dict:
    """Replicated coefficient arrays, pre-extended by ``reach`` so any shard
    can slice its piece ('small data' lives on every chip, paper step 8)."""
    cmode = bc.coeff_mode(p)
    return {c: bc.pad_coeff(jnp.asarray(coeffs[c], dtype=jdtype),
                            reach[c][0], reach[c][1], cmode)
            for c in p.coeffs}


def _pallas_coeff_windows(p: Program, calls, coeffs, origin,
                          shard: ShardSpec, reach: dict) -> list:
    """Per-call local coefficient windows, sliced at the shard origin."""
    out = []
    for call in calls:
        pc = {}
        for c in call.group_coeffs:
            ax = call.coeff_axis[c]
            start = origin[ax] + reach[c][0] - call.pad_lo[ax]
            pc[c] = jax.lax.dynamic_slice(
                coeffs[c], (start,),
                (shard.local_grid[ax] + call.pad_lo[ax] + call.pad_hi[ax],))
        out.append(pc)
    return out


def _pallas_reach(calls, p: Program) -> dict:
    reach = {c: [0, 0] for c in p.coeffs}
    for call in calls:
        for c in call.group_coeffs:
            ax = call.coeff_axis[c]
            reach[c][0] = max(reach[c][0], call.pad_lo[ax])
            reach[c][1] = max(reach[c][1], call.pad_hi[ax])
    return reach


def _stream_graph(p: Program, plan: DataflowPlan, shard: ShardSpec, graph):
    """The plan's dataflow graph, lowered for this shard's topology.

    A sharded stream axis needs *exact* neighbour ghost planes (the region
    halos carry the ring-chain-propagated lo reach), so a graph built
    without the flag must not drive a sharded sweep — rebuild unless the
    caller handed one down from the pipeline."""
    if plan.schedule != "stream":
        return None
    ss = shard.axis_size(STREAM_AXIS) > 1
    if graph is None or bool(graph.stream_sharded) != ss:
        graph = lower_to_dataflow(p, plan, shard.local_grid,
                                  stream_sharded=ss)
    return graph


def _pallas_calls(p: Program, plan: DataflowPlan, local_grid, global_grid,
                  jdtype, graph, time_tile: int = 1, update=None):
    """The plan's kernel calls on the shard-local block.

    Block and stream kernels expose the same geometry contract
    (``group_inputs``/``halo_lo``/``input_pad`` slicing/``origin=``), so
    the SPMD orchestrators below drive either schedule identically; a
    stream sweep additionally chains ``time_tile`` timestep stages when
    the fused-loop ``update`` rule rides in-kernel, and advances the
    graph's effective ``plane_tile`` planes per grid step (demoted against
    the *shard-local* stream extent by ``lower_to_dataflow``)."""
    if plan.schedule == "stream":
        return [build_stream_call(p, region, local_grid, dtype=jdtype,
                                  interpret=plan.interpret,
                                  global_extent=global_grid,
                                  time_tile=time_tile, update=update,
                                  plane_tile=getattr(graph, "plane_tile", 1),
                                  stream_sharded=graph.stream_sharded)
                for region in graph.regions]
    return [build_group_call(p, grp, plan.block, local_grid, dtype=jdtype,
                             interpret=plan.interpret,
                             global_extent=global_grid)
            for grp in plan.groups]


# --------------------------------------------------------------------------
# single program step under shard_map
# --------------------------------------------------------------------------

def lower_sharded(p: Program, plan: DataflowPlan, global_grid,
                  shard: ShardSpec, mesh: Mesh, graph=None):
    """Return fn(fields, scalars, coeffs) running one program step SPMD.

    Schedule-agnostic: ``plan.schedule`` picks block-tiled group kernels or
    plane-sweeping stream kernels per shard (``graph`` optionally hands
    down the pipeline's already-lowered dataflow graph)."""
    global_grid = tuple(int(g) for g in global_grid)
    jdtype = _DTYPES[plan.dtype]
    bnd = p.boundaries()
    backend = plan.backend
    tracer = current_tracer()
    if tracer.enabled:
        tracer.event("ShardLowered", program=p.name, mode="single",
                     backend=backend, mesh=dict(mesh.shape),
                     local_grid="x".join(str(g) for g in shard.local_grid))
    mesh_axes, axis_sizes = shard.mesh_axes, shard.axis_sizes
    out_names = p.output_fields()
    origin_arrs, origin_specs = _origin_inputs(shard)
    scal_spec, pack_scalars = _scalar_io(p, backend)
    in_specs = _in_specs(p, shard, origin_specs, scal_spec)
    out_specs = tuple(P(*mesh_axes) for _ in out_names)
    reach = _coeff_reach(p, shard)

    degen = _degenerate(shard)
    if backend == "pallas":
        graph = _stream_graph(p, plan, shard, graph)
        calls = _pallas_calls(p, plan, shard.local_grid, global_grid,
                              jdtype, graph)
        if not degen:
            reach = _pallas_reach(calls, p)

        def local_fn(svec, fields, coeffs, origs):
            origin = _origin(shard, origs)
            # degenerate mesh: the local pad path, so the graph (and its
            # rounding) bit-matches the single-device lowering
            pc_per_call = (_pad_coeffs(p, calls, coeffs, jdtype) if degen
                           else _pallas_coeff_windows(p, calls, coeffs,
                                                      origin, shard, reach))

            def resolve(call, f, env):
                x = env[f] if f in env else fields[f]
                if degen:
                    return bc.pad_field(x, call.halo_lo, call.halo_hi,
                                        bnd[f], align_hi=call.align_hi), None
                return halo_exchange_pad(
                    x, call.halo_lo, call.halo_hi, call.align_hi,
                    mesh_axes, axis_sizes,
                    periodic=bnd[f] == "periodic"), None

            outputs = _run_groups(p, calls, svec, pc_per_call, resolve,
                                  origin=origin)
            return tuple(outputs[f] for f in out_names)
    elif backend in ("jnp_fused", "jnp_naive"):
        mode = backend.removeprefix("jnp_")

        def local_fn(scal, fields, coeffs, origs):
            origin = _origin(shard, origs)
            shift, coeff = _jnp_step_hooks(p, shard, origin, reach)
            step = lower_jnp_step(p, mode, shift_fn=shift, coeff_fn=coeff)
            outputs = step(fields, scal, coeffs)
            return tuple(outputs[f] for f in out_names)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    smapped = _smap(local_fn, mesh, in_specs, out_specs)

    def run(fields: Mapping, scalars: Mapping | None = None,
            coeffs: Mapping | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        fdict = {f: jnp.asarray(fields[f], dtype=jdtype)
                 for f in p.input_fields()}
        cdict = _host_coeffs(p, coeffs, jdtype, reach)
        res = smapped(pack_scalars(scalars), fdict, cdict, origin_arrs)
        return dict(zip(out_names, res))

    return run


# --------------------------------------------------------------------------
# fused time loop under shard_map (carry-resident halo exchange)
# --------------------------------------------------------------------------

def lower_sharded_time_loop(p: Program, plan: DataflowPlan, global_grid,
                            spec: TimeLoopSpec, update, mesh: Mesh,
                            graph=None):
    """Return fn(fields, scalars, coeffs) -> final fields after
    ``spec.steps`` distributed iterations — ONE jitted dispatch.

    Structure (all inside ``shard_map``, so it traces once per compile):

        carry = per-field local buffers padded to the worst-group halo
        fori_loop body:
            refresh halo slabs from the carry interiors (ppermute rings /
                local wrap / zeros, axis by axis so corners are exact)
            run the plan's kernels against the refreshed buffers
            trace ``update`` once; write the new interiors back

    The final interiors are sliced out after the loop; no per-step host
    sync, no per-step re-dispatch, no re-tracing of ``update``.

    Schedule-agnostic: ``plan.schedule = "stream"`` swaps the block-tiled
    group kernels for per-shard plane-sweeping stream kernels behind the
    same refresh-then-compute contract — still one exchange per field per
    step.  With an effective ``time_tile = T > 1`` on the dataflow graph,
    each loop iteration runs ONE chained sweep advancing T steps (all T
    updates applied in-kernel; the carry padding covers the chain's
    accumulated halos, so still one exchange per field per *chain*), the
    loop runs ``spec.steps // T`` iterations, and a ``steps % T``
    remainder runs once after it through a shallower chain.  ``graph``
    optionally hands down the pipeline's already-lowered dataflow graph.
    """
    shard = spec.shard
    if shard is None:
        raise ValueError("spec has no ShardSpec; use the local lowerings")
    update = adapt_update(update)
    global_grid = tuple(int(g) for g in global_grid)
    tracer = current_tracer()
    if tracer.enabled:
        tracer.event("ShardLowered", program=p.name, mode="loop",
                     backend=plan.backend, mesh=dict(mesh.shape),
                     local_grid="x".join(str(g) for g in shard.local_grid),
                     steps=int(spec.steps))
    ndim = p.ndim
    jdtype = _DTYPES[plan.dtype]
    bnd = p.boundaries()
    backend = plan.backend
    mesh_axes, axis_sizes = shard.mesh_axes, shard.axis_sizes
    local_grid = shard.local_grid
    fpad = spec.field_pad
    align = spec.align_hi or (0,) * ndim
    interior = {f: tuple(slice(int(fpad[f][a, 0]),
                               int(fpad[f][a, 0]) + local_grid[a])
                         for a in range(ndim))
                for f in spec.persistent}
    carry_pads = {f: tuple((int(fpad[f][a, 0]), int(fpad[f][a, 1]))
                           for a in range(ndim))
                  for f in spec.persistent}

    def _needs_refresh(f) -> bool:
        # a field's carry halos go stale each step only if they hold
        # wraparound values (periodic) or neighbour data (sharded axis);
        # zero halos on unsharded axes are invariant — skipping their
        # rebuild also lets a degenerate 1x..x1 mesh fold to the exact
        # single-device graph
        for a in range(ndim):
            lo = int(fpad[f][a, 0])
            hi = int(fpad[f][a, 1]) - int(align[a])
            if lo == 0 and hi == 0:
                continue
            if bnd[f] == "periodic" or shard.axis_size(a) > 1:
                return True
        return False

    refreshed = {f for f in spec.persistent if _needs_refresh(f)}

    def refresh(f, carry_f):
        # carry-resident halo refresh: lo/hi halos per the field's
        # boundary, zero lane-alignment slab on the hi side
        if f not in refreshed:
            return carry_f
        return halo_exchange_pad(
            carry_f[interior[f]], fpad[f][:, 0],
            [int(fpad[f][a, 1]) - int(align[a]) for a in range(ndim)],
            align, mesh_axes, axis_sizes, periodic=bnd[f] == "periodic")

    origin_arrs, origin_specs = _origin_inputs(shard)
    scal_spec, pack_scalars = _scalar_io(p, backend)
    in_specs = _in_specs(p, shard, origin_specs, scal_spec)
    out_specs = tuple(P(*mesh_axes) for _ in spec.persistent)

    degen = _degenerate(shard)
    chain = 1
    epilogue_calls = None
    if backend == "pallas":
        graph = _stream_graph(p, plan, shard, graph)
        T = int(getattr(graph, "time_tile", 1)) if graph is not None else 1
        if T > 1:
            # temporally-blocked chain: legality implies a single region
            # (see dataflow.chain_split_reason); one chained sweep per loop
            # iteration advances T steps, updates applied in-kernel
            chain = T
            calls = _pallas_calls(p, plan, local_grid, global_grid, jdtype,
                                  graph, time_tile=T, update=update)
            rem = int(spec.steps) % T
            if rem:
                epilogue_calls = _pallas_calls(p, plan, local_grid,
                                               global_grid, jdtype, graph,
                                               time_tile=rem, update=update)
        else:
            calls = _pallas_calls(p, plan, local_grid, global_grid, jdtype,
                                  graph)
        reach = (_coeff_reach(p, shard) if degen
                 else _pallas_reach(calls + (epilogue_calls or []), p))

        def make_step(origin, coeffs, calls_):
            # degenerate mesh: the local pad path, so the graph (and its
            # rounding) bit-matches the single-device fused loop
            pc_per_call = (_pad_coeffs(p, calls_, coeffs, jdtype) if degen
                           else _pallas_coeff_windows(p, calls_, coeffs,
                                                      origin, shard, reach))

            if getattr(calls_[0], "returns_fields", False):
                # chained stream sweep: ONE call advances every persistent
                # field by its full chain depth and returns the new fields
                call = calls_[0]

                def step(fresh, svec):
                    padded = {f: fresh[f] for f in call.group_inputs}
                    return call(padded, svec, pc_per_call[0], origin=origin,
                                input_pad={f: fpad[f]
                                           for f in call.group_inputs})

                step.returns_fields = True
                return step

            def step(fresh, svec):
                def resolve(call, f, env):
                    if f in fresh:      # persistent: window from the carry
                        return fresh[f], fpad[f]
                    # transient inter-group: exchange to the call's geometry
                    if degen:
                        return bc.pad_field(env[f], call.halo_lo,
                                            call.halo_hi, bnd[f],
                                            align_hi=call.align_hi), None
                    return halo_exchange_pad(
                        env[f], call.halo_lo, call.halo_hi, call.align_hi,
                        mesh_axes, axis_sizes,
                        periodic=bnd[f] == "periodic"), None

                return _run_groups(p, calls_, svec, pc_per_call, resolve,
                                   origin=origin)

            step.returns_fields = False
            return step
    elif backend in ("jnp_fused", "jnp_naive"):
        mode = backend.removeprefix("jnp_")
        calls = [None]
        reach = _coeff_reach(p, shard)

        def make_step(origin, coeffs, calls_):
            shift, coeff = _jnp_step_hooks(p, shard, origin, reach)
            raw = lower_jnp_step(p, mode, prepad=fpad, shift_fn=shift,
                                 coeff_fn=coeff)

            def step(fresh, scal):
                return raw(fresh, scal, coeffs)

            step.returns_fields = False
            return step
    else:
        raise ValueError(f"unknown backend {backend!r}")

    def local_fn(scal, fields, coeffs, origs):
        origin = _origin(shard, origs)
        step = make_step(origin, coeffs, calls)
        step_epi = (make_step(origin, coeffs, epilogue_calls)
                    if epilogue_calls is not None else None)
        # initial carry: zero-padded; the loop body refreshes halos before
        # the first compute, so the fill value is never observed
        carry = {f: jnp.pad(fields[f], carry_pads[f])
                 for f in spec.persistent}

        def advance(carry, stepfn):
            fresh = {f: refresh(f, carry[f]) for f in spec.persistent}
            if stepfn.returns_fields:
                # chained sweep: the kernel already applied every update
                new = stepfn(fresh, scal)
            else:
                outputs = stepfn(fresh, scal)
                cur = {f: fresh[f][interior[f]] for f in spec.persistent}
                new = dict(cur)
                # the packed pallas scalar vector unpacks back to the
                # name->value dict the update rule sees everywhere else
                sdict = ({s: scal[i] for i, s in enumerate(p.scalars)}
                         if backend == "pallas" else scal)
                if getattr(update, "_takes_origin", False) and not degen:
                    # shard-aware rules (the serving bucket refresh) mask
                    # in global coordinates; the degenerate mesh keeps the
                    # local form so its graph stays bit-identical
                    new.update(update(cur, outputs, sdict, origin=origin))
                else:
                    new.update(update(cur, outputs, sdict))
            out = {}
            for f in spec.persistent:
                if spec.carry_write == "inplace":
                    out[f] = fresh[f].at[interior[f]].set(
                        jnp.asarray(new[f], dtype=jdtype))
                else:   # "repad": halos are rebuilt next iteration anyway
                    out[f] = jnp.pad(jnp.asarray(new[f], dtype=jdtype),
                                     carry_pads[f])
            return out

        carry = jax.lax.fori_loop(0, int(spec.steps) // chain,
                                  lambda _, c: advance(c, step), carry)
        if step_epi is not None:
            carry = advance(carry, step_epi)
        return tuple(carry[f][interior[f]] for f in spec.persistent)

    smapped = _smap(local_fn, mesh, in_specs, out_specs)

    def run(fields: Mapping, scalars: Mapping | None = None,
            coeffs: Mapping | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        fdict = {f: jnp.asarray(fields[f], dtype=jdtype)
                 for f in p.input_fields()}
        cdict = _host_coeffs(p, coeffs, jdtype, reach)
        res = smapped(pack_scalars(scalars), fdict, cdict, origin_arrs)
        return dict(zip(spec.persistent, res))

    return run


# --------------------------------------------------------------------------
# deprecated standalone entry point
# --------------------------------------------------------------------------

def make_sharded_executor(p: Program, global_grid, mesh: Mesh,
                          mesh_axes: Sequence, *,
                          plan: DataflowPlan | None = None,
                          backend: str = "pallas",
                          interpret: bool = True, dtype: str = "float32"):
    """Deprecated: use ``compile_program(p, grid, mesh=..., mesh_axes=...)``.

    Kept as a thin forwarding wrapper so existing callers keep working;
    the returned executable is a :class:`CompiledStencil` with the legacy
    ``local_grid`` / ``mesh_axes`` / ``field_spec`` attributes attached.
    """
    warnings.warn(
        "make_sharded_executor is deprecated; call "
        "compile_program(p, grid, mesh=..., mesh_axes=...) instead",
        DeprecationWarning, stacklevel=2)
    from .pipeline import CompileOptions, compile_program
    ex = compile_program(p, global_grid, options=CompileOptions(
        backend=backend, plan=plan, interpret=interpret, dtype=dtype,
        mesh=mesh, mesh_axes=mesh_axes))
    ex.local_grid = ex.shard.local_grid
    ex.mesh_axes = ex.shard.mesh_axes
    ex.field_spec = P(*ex.shard.mesh_axes)
    return ex
