"""Stencil IR — the analogue of the MLIR ``stencil`` dialect (paper §2.2.1).

A :class:`Program` is a set of typed grid fields plus an ordered list of
:class:`StencilOp`, each producing one output field from an expression tree
over relative-offset :class:`Access` nodes — exactly the information content
of ``stencil.load / stencil.apply / stencil.access / stencil.return /
stencil.store``.  Everything downstream (the planner = HLS-dialect analogue,
the jnp and Pallas backends, the distributed executor) consumes this IR.

Semantics
---------
* All fields share one logical grid of rank ``ndim`` (1..3).
* ``Access(field, offset)`` reads the field at ``index + offset``;
  out-of-domain reads return 0 (zero-halo convention, applied identically by
  every backend, including the distributed one via ``lax.ppermute``'s
  zero-fill at torus edges).
* Ops may read fields produced by *earlier* ops in the same program — the
  dependency structure the paper calls out for tracer advection.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


class BinOpKind(str, enum.Enum):
    ADD = "add"
    SUB = "sub"
    MUL = "mul"
    DIV = "div"
    POW = "pow"
    MIN = "min"
    MAX = "max"


class UnOpKind(str, enum.Enum):
    NEG = "neg"
    ABS = "abs"
    SQRT = "sqrt"
    EXP = "exp"
    LOG = "log"
    TANH = "tanh"
    SQUARE = "square"
    SIGN = "sign"


class CmpKind(str, enum.Enum):
    LT = "lt"
    LE = "le"
    GT = "gt"
    GE = "ge"
    EQ = "eq"


@dataclasses.dataclass(frozen=True)
class Expr:
    """Base class: all nodes are frozen dataclasses, hashable for CSE."""

    def children(self) -> tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float


@dataclasses.dataclass(frozen=True)
class ScalarRef(Expr):
    """A runtime scalar argument ('small data' the paper copies to BRAM)."""

    name: str


@dataclasses.dataclass(frozen=True)
class Access(Expr):
    """stencil.access: read ``field`` at relative ``offset``."""

    field: str
    offset: tuple  # tuple[int, ...] of length ndim


@dataclasses.dataclass(frozen=True)
class CoeffRef(Expr):
    """Read a 1-D coefficient array along one grid axis at a relative offset.

    This is the paper's 'small data' (step 8): per-level coefficients such as
    MONC's tzc1(k)/tzc2(k), copied into local memory (BRAM on FPGA, VMEM/SMEM
    resident here) rather than streamed from external memory.
    """

    coeff: str
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    kind: BinOpKind
    lhs: Expr
    rhs: Expr

    def children(self):
        return (self.lhs, self.rhs)


@dataclasses.dataclass(frozen=True)
class UnOp(Expr):
    kind: UnOpKind
    operand: Expr

    def children(self):
        return (self.operand,)


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    kind: CmpKind
    lhs: Expr
    rhs: Expr

    def children(self):
        return (self.lhs, self.rhs)


@dataclasses.dataclass(frozen=True)
class Select(Expr):
    pred: Expr
    on_true: Expr
    on_false: Expr

    def children(self):
        return (self.pred, self.on_true, self.on_false)


# --------------------------------------------------------------------------
# Program structure
# --------------------------------------------------------------------------


class FieldRole(str, enum.Enum):
    INPUT = "input"          # stencil field input       (paper step 1)
    OUTPUT = "output"        # stencil field output
    TEMP = "temp"            # produced AND consumed internally


@dataclasses.dataclass
class FieldDecl:
    name: str
    role: FieldRole
    dtype: str = "float32"
    # how reads outside the domain resolve: "zero" (historical convention)
    # or "periodic" (torus wraparound) — see repro.core.boundary
    boundary: str = "zero"


@dataclasses.dataclass
class StencilOp:
    """One ``stencil.apply`` producing a single output field.

    The paper's transformation *splits* multi-field applies into per-field
    ops (step 4); this IR is born already in that normal form — the frontend
    emits one op per assigned output.
    """

    out: str
    expr: Expr
    name: str = ""

    def accesses(self) -> list[Access]:
        out: list[Access] = []

        def rec(e: Expr):
            if isinstance(e, Access):
                out.append(e)
            for c in e.children():
                rec(c)

        rec(self.expr)
        return out

    def coeff_refs(self) -> list["CoeffRef"]:
        out: list[CoeffRef] = []

        def rec(e: Expr):
            if isinstance(e, CoeffRef):
                out.append(e)
            for c in e.children():
                rec(c)

        rec(self.expr)
        return out


@dataclasses.dataclass
class Program:
    name: str
    ndim: int
    fields: dict            # name -> FieldDecl
    scalars: list           # list[str] runtime scalar names, ordered
    ops: list               # list[StencilOp], in definition order
    coeffs: dict = dataclasses.field(default_factory=dict)  # name -> axis

    # ------------------------------------------------------------------
    # Derived structure
    # ------------------------------------------------------------------
    def validate(self) -> None:
        defined = {n for n, f in self.fields.items() if f.role == FieldRole.INPUT}
        produced: set = set()
        for op in self.ops:
            if op.out not in self.fields:
                raise ValueError(f"op writes undeclared field {op.out!r}")
            for a in op.accesses():
                if len(a.offset) != self.ndim:
                    raise ValueError(
                        f"offset {a.offset} has rank {len(a.offset)}, program is {self.ndim}-D")
                if a.field not in self.fields:
                    raise ValueError(f"access to undeclared field {a.field!r}")
                if a.field not in defined and a.field not in produced:
                    raise ValueError(
                        f"op {op.name or op.out!r} reads {a.field!r} before it is produced")
            for c in op.coeff_refs():
                if c.coeff not in self.coeffs:
                    raise ValueError(f"access to undeclared coeff {c.coeff!r}")
            produced.add(op.out)
        for n, f in self.fields.items():
            if f.role in (FieldRole.OUTPUT, FieldRole.TEMP) and n not in produced:
                raise ValueError(f"declared output {n!r} never produced")
        from .boundary import validate_boundaries
        validate_boundaries(self)

    def boundaries(self) -> dict:
        """field name -> boundary kind ("zero" | "periodic")."""
        return {n: f.boundary for n, f in self.fields.items()}

    def is_torus(self) -> bool:
        """True when every field is periodic (the whole domain wraps)."""
        return all(f.boundary == "periodic" for f in self.fields.values())

    def with_boundary(self, spec) -> "Program":
        """A copy of this program with boundaries replaced.

        ``spec`` is either a single kind applied to every field (the usual
        torus/zero toggle) or a mapping ``{field: kind}`` overriding only
        the named fields.  The copy is re-validated.
        """
        if isinstance(spec, str):
            spec = {n: spec for n in self.fields}
        unknown = set(spec) - set(self.fields)
        if unknown:
            raise ValueError(f"with_boundary: unknown field(s) "
                             f"{sorted(unknown)}; fields are "
                             f"{sorted(self.fields)}")
        fields = {n: dataclasses.replace(f, boundary=spec.get(n, f.boundary))
                  for n, f in self.fields.items()}
        p = Program(name=self.name, ndim=self.ndim, fields=fields,
                    scalars=list(self.scalars), ops=list(self.ops),
                    coeffs=dict(self.coeffs))
        p.validate()
        return p

    def input_fields(self) -> list:
        return [n for n, f in self.fields.items() if f.role == FieldRole.INPUT]

    def output_fields(self) -> list:
        return [n for n, f in self.fields.items() if f.role == FieldRole.OUTPUT]

    def temp_fields(self) -> list:
        return [n for n, f in self.fields.items() if f.role == FieldRole.TEMP]

    def op_producing(self, field: str):
        for i, op in enumerate(self.ops):
            if op.out == field:
                return i
        return None

    def flops_per_point(self) -> int:
        """Arithmetic ops per grid point (one pass over all ops)."""
        total = 0
        for op in self.ops:
            total += count_flops(op.expr)
        return total

    # ------------------------------------------------------------------
    # Pretty printing (stencil-dialect-like, for docs/debugging)
    # ------------------------------------------------------------------
    def to_text(self) -> str:
        lines = [f"stencil.program @{self.name} ndim={self.ndim} {{"]
        for s in self.scalars:
            lines.append(f"  %{s} = stencil.scalar_arg")
        for n, f in self.fields.items():
            if f.role == FieldRole.INPUT:
                lines.append(f"  %{n} = stencil.load : field<{f.dtype}>")
        for op in self.ops:
            lines.append(f"  %{op.out} = stencil.apply {{")
            lines.append(f"    {format_expr(op.expr)}")
            lines.append("  }")
        for n in self.output_fields():
            lines.append(f"  stencil.store %{n}")
        lines.append("}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Expression helpers
# --------------------------------------------------------------------------

_FLOP_COST = {
    BinOpKind.ADD: 1, BinOpKind.SUB: 1, BinOpKind.MUL: 1, BinOpKind.DIV: 1,
    BinOpKind.POW: 10, BinOpKind.MIN: 1, BinOpKind.MAX: 1,
}
_UNOP_COST = {
    UnOpKind.NEG: 1, UnOpKind.ABS: 1, UnOpKind.SQRT: 4, UnOpKind.EXP: 8,
    UnOpKind.LOG: 8, UnOpKind.TANH: 10, UnOpKind.SQUARE: 1, UnOpKind.SIGN: 1,
}


def count_flops(e: Expr) -> int:
    n = 0
    if isinstance(e, BinOp):
        n += _FLOP_COST[e.kind]
    elif isinstance(e, UnOp):
        n += _UNOP_COST[e.kind]
    elif isinstance(e, (Cmp, Select)):
        n += 1
    for c in e.children():
        n += count_flops(c)
    return n


def format_expr(e: Expr) -> str:
    if isinstance(e, Const):
        return repr(e.value)
    if isinstance(e, ScalarRef):
        return f"%{e.name}"
    if isinstance(e, Access):
        off = ",".join(str(o) for o in e.offset)
        return f"%{e.field}[{off}]"
    if isinstance(e, CoeffRef):
        return f"%{e.coeff}<{e.offset:+d}>"
    if isinstance(e, BinOp):
        return f"({format_expr(e.lhs)} {e.kind.value} {format_expr(e.rhs)})"
    if isinstance(e, UnOp):
        return f"{e.kind.value}({format_expr(e.operand)})"
    if isinstance(e, Cmp):
        return f"({format_expr(e.lhs)} {e.kind.value} {format_expr(e.rhs)})"
    if isinstance(e, Select):
        return (f"select({format_expr(e.pred)}, {format_expr(e.on_true)}, "
                f"{format_expr(e.on_false)})")
    raise TypeError(type(e))


def map_expr(e: Expr, fn: Callable[[Expr], Expr | None]) -> Expr:
    """Bottom-up rewrite: ``fn`` may return a replacement or None to keep."""
    if isinstance(e, BinOp):
        e = BinOp(e.kind, map_expr(e.lhs, fn), map_expr(e.rhs, fn))
    elif isinstance(e, UnOp):
        e = UnOp(e.kind, map_expr(e.operand, fn))
    elif isinstance(e, Cmp):
        e = Cmp(e.kind, map_expr(e.lhs, fn), map_expr(e.rhs, fn))
    elif isinstance(e, Select):
        e = Select(map_expr(e.pred, fn), map_expr(e.on_true, fn),
                   map_expr(e.on_false, fn))
    r = fn(e)
    return e if r is None else r


def expr_fields(e: Expr) -> set:
    return {a.field for a in _collect_accesses(e)}


def _collect_accesses(e: Expr) -> list:
    out = []

    def rec(x):
        if isinstance(x, Access):
            out.append(x)
        for c in x.children():
            rec(c)

    rec(e)
    return out
