# Stencil-HMLS core: stencil IR (dialect analogue), dataflow plan + stream
# graph (HLS-dialect analogue), jnp/Pallas backends, distributed executor.
from .frontend import (CoeffHandle, ExprHandle, FieldHandle, ProgramBuilder,
                       absolute, exp, log, maximum, minimum, sign, sqrt,
                       tanh, where)
from .boundary import BOUNDARIES
from .dataflow import (StreamGraph, StreamRegion, chain_split_reason,
                       effective_plane_tile, effective_time_tile,
                       lower_to_dataflow, plane_split_reason)
from .ir import Program
from .pipeline import (CompiledStencil, CompileOptions, TileDemotionWarning,
                       compile_program, run_time_loop)
from .schedule import (DataflowPlan, ShardSpec, StreamSpec, TimeLoopSpec,
                       adapt_update, auto_plan, make_shard_spec,
                       plan_from_dict, plan_time_loop, plan_to_dict,
                       program_fingerprint, shard_local_grid)
from .tune import PlanCache, TuneConfig, TuneResult, get_tuned_plan, tune_plan
