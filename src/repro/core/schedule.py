"""DataflowPlan — the HLS-dialect analogue (paper §3.1).

Where the paper's HLS dialect records FPGA decisions (streams, pipeline II,
unroll, array_partition, AXI bundles), the plan records their TPU analogues:

  hls.create_stream / dataflow  ->  fuse-group boundaries + Pallas pipeline
  hls.pipeline(II)              ->  grid/block shape (VMEM tiling)
  hls.unroll                    ->  in-tile vectorisation (VPU lanes; implicit)
  hls.array_partition           ->  window layout (halo), lane alignment
  hls.interface / bundles       ->  PartitionSpec per field (chips = banks)

A plan is pure data: both backends and the distributed executor consume it,
the auto-tuner (:mod:`repro.core.tune`) searches over it by measurement, and
:func:`plan_to_dict` / :func:`plan_from_dict` round-trip it through the
tuner's persistent JSON plan cache.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Sequence

import numpy as np

from .. import hw
from .ir import Program
from .passes import _zeros, infer_halo, stage_split


SCHEDULES = ("block", "stream")


@dataclasses.dataclass
class StreamSpec:
    """Shift-register geometry of a ``schedule="stream"`` plan (the paper's
    HLS-dialect window buffers, §3.2 Fig. 2).

    Derived from the stencil IR by :func:`repro.core.dataflow.
    lower_to_dataflow` and carried on the plan so the tuner's JSON cache
    round-trips the full streaming decision:

    * ``regions`` — the *legalised* fuse groups: plan groups split wherever
      an in-group temp is read at a positive stream offset (would need the
      future) or a periodic temp at a negative one (wraparound is not yet
      resident).
    * ``depths`` — per region, each input field's rolling window-buffer
      depth in planes: the field's reach behind the newest plane plus the
      region's lead plus one (``lo + lead + 1``); every input plane is
      fetched from HBM exactly once and reused across the full depth.
    * ``rings`` — per region, ring-buffer depths for temps consumed at past
      planes (``1 + max back-reference``); streamed dependencies replace
      the block schedule's overlapped-tiling recompute.
    * ``leads`` — per region, how many planes ahead of the output plane the
      stream front runs (the hi-side stream halo).
    * ``time_tile`` — the *effective* temporal-blocking depth: how many time
      steps one sweep actually chains (the paper's pipelined timestep compute
      regions).  The plan's ``time_tile`` records the request; legalisation
      (:func:`repro.core.dataflow.chain_split_reason`) demotes it to 1 here
      when the chain cannot stream in one sweep (multiple regions, periodic
      wraparound, non-persistent inputs).
    * ``plane_tile`` — the *effective* spatial-unroll width: how many
      consecutive planes one sweep grid step DMAs and computes (the paper's
      parallel processing elements consuming multiple contiguous points per
      cycle).  The plan's ``plane_tile`` records the request;
      :func:`repro.core.dataflow.plane_split_reason` demotes it to 1 here
      when a P-plane step would overrun the (shard-local) stream extent.
    """

    axis: int = 0
    regions: tuple = ()
    depths: tuple = ()
    rings: tuple = ()
    leads: tuple = ()
    time_tile: int = 1
    plane_tile: int = 1

    def __post_init__(self):
        self.regions = tuple(tuple(int(i) for i in r) for r in self.regions)
        self.depths = tuple({str(f): int(d) for f, d in d.items()}
                            for d in self.depths)
        self.rings = tuple({str(f): int(d) for f, d in d.items()}
                           for d in self.rings)
        self.leads = tuple(int(v) for v in self.leads)
        self.time_tile = max(1, int(self.time_tile))
        self.plane_tile = max(1, int(self.plane_tile))


def stream_spec_to_dict(s: StreamSpec | None) -> dict | None:
    if s is None:
        return None
    return {
        "axis": int(s.axis),
        "regions": [list(r) for r in s.regions],
        "depths": [dict(d) for d in s.depths],
        "rings": [dict(d) for d in s.rings],
        "leads": list(s.leads),
        "time_tile": int(s.time_tile),
        "plane_tile": int(s.plane_tile),
    }


def stream_spec_from_dict(d: dict | None) -> StreamSpec | None:
    if d is None:
        return None
    return StreamSpec(axis=int(d.get("axis", 0)),
                      regions=d.get("regions", ()),
                      depths=d.get("depths", ()),
                      rings=d.get("rings", ()),
                      leads=d.get("leads", ()),
                      time_tile=int(d.get("time_tile", 1)),
                      plane_tile=int(d.get("plane_tile", 1)))


@dataclasses.dataclass
class DataflowPlan:
    # fuse groups: ordered list of lists of op indices
    groups: list
    # output tile shape per axis (the VMEM block)
    block: tuple
    # dtype for field storage/compute
    dtype: str = "float32"
    # backend: "pallas" | "jnp_fused" | "jnp_naive"
    backend: str = "pallas"
    # run pallas in interpret mode (CPU container) — real runs set False
    interpret: bool = True
    # distributed layout: mesh axis name per grid axis (None entry =
    # unsharded axis).  ``None`` means fully unsharded; stored tuples are
    # normalised to the program's ndim via :meth:`mesh_axes_for` rather than
    # assuming 3-D (2-D programs get 2-tuples).
    mesh_axes: tuple | None = None
    # exchange halos every k steps with k-wide halos (comm amortisation)
    halo_every: int = 1
    # pallas iteration schedule: "block" tiles the output and fetches
    # overlapping VMEM windows per tile; "stream" iterates the grid over
    # the outer axis and keeps rolling shift-register window buffers in the
    # kernel carry (each input plane fetched once, the paper's headline)
    schedule: str = "block"
    # shift-register geometry when schedule == "stream" (None = derive at
    # compile time from the fuse groups)
    stream: StreamSpec | None = None
    # temporal blocking: pipeline T time steps through one stream sweep
    # (window-buffer depths and halo margins accumulate per chained step;
    # the fused loop advances steps // T outer iterations).  Requested
    # depth; the legalised effective depth lives on ``stream.time_tile``.
    time_tile: int = 1
    # spatial unrolling: DMA + compute P consecutive planes per stream
    # sweep grid step (the grid shrinks to ceil(n_steps / P)).  Requested
    # width; the effective width lives on ``stream.plane_tile``.
    plane_tile: int = 1

    def __post_init__(self):
        if self.mesh_axes is not None:
            self.mesh_axes = tuple(self.mesh_axes)
        self.block = tuple(self.block)
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; valid: "
                             + ", ".join(repr(s) for s in SCHEDULES))
        self.time_tile = int(self.time_tile)
        if self.time_tile < 1:
            raise ValueError(f"time_tile must be >= 1, got {self.time_tile}")
        if self.time_tile > 1 and self.schedule != "stream":
            raise ValueError(
                "time_tile > 1 is temporal blocking through the stream "
                "sweep; it requires schedule='stream' (the block schedule "
                f"has no chained lowering), got schedule={self.schedule!r}")
        self.plane_tile = int(self.plane_tile)
        if self.plane_tile < 1:
            raise ValueError(
                f"plane_tile must be >= 1, got {self.plane_tile}")
        if self.plane_tile > 1 and self.schedule != "stream":
            raise ValueError(
                "plane_tile > 1 is spatial unrolling of the stream sweep; "
                "it requires schedule='stream' (the block schedule has no "
                f"multi-plane sweep), got schedule={self.schedule!r}")

    def mesh_axes_for(self, ndim: int) -> tuple:
        """Mesh axis names normalised to ``ndim`` entries (None = unsharded)."""
        return normalize_mesh_axes(self.mesh_axes, ndim)

    def describe(self) -> str:
        g = ", ".join("{" + ",".join(map(str, grp)) + "}" for grp in self.groups)
        ma = self.mesh_axes_for(len(self.block))
        tt = f", time_tile={self.time_tile}" if self.time_tile > 1 else ""
        pt = f", plane_tile={self.plane_tile}" if self.plane_tile > 1 else ""
        return (f"plan(groups=[{g}], block={self.block}, backend={self.backend}, "
                f"schedule={self.schedule}{tt}{pt}, mesh_axes={ma})")


# --------------------------------------------------------------------------
# Plan serialisation + program fingerprinting (the tuner's cache layer)
# --------------------------------------------------------------------------

#: Version of the serialised plan layout.  Bumped whenever a field is added
#: or its meaning changes (v2: ``schedule`` + ``StreamSpec``; v3: temporal
#: blocking — ``time_tile`` on the plan and the effective depth on the
#: stream spec; v4: spatial unrolling — ``plane_tile`` on the plan and the
#: effective width on the stream spec).  Deserialising is tolerant —
#: unknown keys are ignored, missing new keys get their defaults — so the
#: version mainly lets cache layers treat *stale* records as misses rather
#: than guessing at their semantics.
PLAN_SCHEMA_VERSION = 4


def plan_to_dict(plan: DataflowPlan) -> dict:
    """JSON-safe encoding of a plan (round-trips via :func:`plan_from_dict`)."""
    return {
        "schema": PLAN_SCHEMA_VERSION,
        "groups": [[int(i) for i in grp] for grp in plan.groups],
        "block": [int(b) for b in plan.block],
        "dtype": plan.dtype,
        "backend": plan.backend,
        "interpret": bool(plan.interpret),
        "mesh_axes": (None if plan.mesh_axes is None
                      else list(plan.mesh_axes)),
        "halo_every": int(plan.halo_every),
        "schedule": plan.schedule,
        "stream": stream_spec_to_dict(plan.stream),
        "time_tile": int(plan.time_tile),
        "plane_tile": int(plan.plane_tile),
    }


def plan_from_dict(d: dict) -> DataflowPlan:
    """Tolerant decoding: only the keys this version knows are read (future
    extras are ignored), and keys a past version never wrote fall back to
    the field defaults — a pre-``schedule`` record deserialises as a
    ``"block"`` plan instead of crashing."""
    ma = d.get("mesh_axes")
    return DataflowPlan(
        groups=[list(grp) for grp in d["groups"]],
        block=tuple(d["block"]),
        dtype=d.get("dtype", "float32"),
        backend=d.get("backend", "pallas"),
        interpret=bool(d.get("interpret", True)),
        mesh_axes=None if ma is None else tuple(ma),
        halo_every=int(d.get("halo_every", 1)),
        schedule=d.get("schedule", "block"),
        stream=stream_spec_from_dict(d.get("stream")),
        time_tile=int(d.get("time_tile", 1)),
        plane_tile=int(d.get("plane_tile", 1)),
    )


def program_fingerprint(p: Program) -> str:
    """Stable content hash of a program's *semantics* (ops, fields, scalars,
    coefficient axes, field dtypes) — the tuner's cache key component.  Two
    programs with the same fingerprint lower identically, so a tuned plan is
    transferable between them."""
    parts = [p.to_text()]
    parts += [f"field:{n}:{f.role.value}:{f.dtype}:{f.boundary}"
              for n, f in sorted(p.fields.items())]
    parts += [f"coeff:{c}:{ax}" for c, ax in sorted(p.coeffs.items())]
    parts.append(f"scalars:{','.join(p.scalars)}")
    return hashlib.sha256("\n".join(parts).encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Grid bucketing (the serving layer's shape quantisation)
# --------------------------------------------------------------------------

def program_reach(p: Program) -> np.ndarray:
    """Transitive stencil reach of ``p`` as an ``(ndim, 2)`` array: how far
    any output cell's value depends on input cells, through every
    producer->consumer chain.  This is the halo a serving bucket must keep
    between a request's true grid and the bucket edge so that no in-domain
    read ever observes the bucket boundary."""
    return np.array(infer_halo(p, range(len(p.ops))).input_halo)


def quantize_extent(n: int, *, lane_axis: bool = False,
                    lane: int = hw.LANE) -> int:
    """Round one grid extent up to its bucket quantum.

    Small extents round to the next power of two (few buckets, bounded
    padding waste); extents at or beyond the lane width round to lane
    multiples on the lane axis (the 512-bit-burst analogue) and to
    32-multiples elsewhere — so arbitrarily varied request grids land on a
    small, hardware-aligned set of compiled shapes.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"extent must be >= 1, got {n}")
    quantum = lane if lane_axis else 32
    if n >= quantum:
        return hw.align_up(n, quantum)
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Placement of one request grid inside a quantised serving bucket.

    The request's true ``grid`` sits at ``offset`` (the program's lo-side
    reach) inside ``bucket``; the slab below the offset and everything past
    ``offset + grid`` is boundary extension the serving layer fills (zeros
    or wraparound) and re-normalises every fused step, so in-domain reads
    never observe the bucket edge.
    """

    grid: tuple
    bucket: tuple
    offset: tuple

    def interior(self) -> tuple:
        """Slices selecting the true grid out of a bucket-shaped array."""
        return tuple(slice(o, o + g) for o, g in zip(self.offset, self.grid))


def bucket_for(p: Program, grid: Sequence[int], *,
               lane: int = hw.LANE) -> BucketSpec:
    """Quantised serving bucket for ``grid``: true extent plus the program's
    lo/hi reach, rounded up per :func:`quantize_extent`.  Requests whose
    grids share a bucket share one compiled executor."""
    grid = tuple(int(g) for g in grid)
    if len(grid) != p.ndim:
        raise ValueError(f"grid rank {len(grid)} != program ndim {p.ndim}")
    reach = program_reach(p)
    bucket, offset = [], []
    for a, g in enumerate(grid):
        lo, hi = int(reach[a, 0]), int(reach[a, 1])
        bucket.append(quantize_extent(g + lo + hi,
                                      lane_axis=(a == p.ndim - 1), lane=lane))
        offset.append(lo)
    return BucketSpec(grid=grid, bucket=tuple(bucket), offset=tuple(offset))


def mesh_fingerprint(mesh, mesh_axes) -> str:
    """Stable encoding of a mesh topology for cache keys.

    Two topologies of the same device count (2x4 vs 4x2, or different
    grid-axis assignments) shard different local blocks and measure
    different collectives — plans and executors compiled under one must
    never serve the other.  ``"none"`` = unsharded/local."""
    if mesh is None:
        return "none"
    axes = tuple(mesh_axes if mesh_axes is not None else mesh.axis_names)
    return ",".join(f"{a or '-'}:{1 if a is None else int(mesh.shape[a])}"
                    for a in axes)


def bucket_fingerprint(p: Program, bucket: Sequence[int], *,
                       backend: str, dtype: str = "float32",
                       interpret: bool = True, schedule: str | None = None,
                       steps: int | None = None,
                       mesh=None, mesh_axes=None,
                       plane_tile: int | None = None) -> str:
    """Cache key of one serving-bucket executor: program semantics
    (boundaries included, via :func:`program_fingerprint`), bucket shape,
    backend/compile options, fused depth, requested sweep unroll width
    (``plane_tile`` — executors with different sweep geometry never share
    a slot), mesh topology (:func:`mesh_fingerprint` — a sharded executor
    must never serve a local request or a different topology), and the
    plan schema version — a record written by another plan layout must
    read as a miss, never as a silently misdecoded plan."""
    return "|".join([
        "serve",
        program_fingerprint(p),
        "bucket=" + "x".join(str(int(b)) for b in bucket),
        f"backend={backend}",
        f"dtype={dtype}",
        f"interpret={int(bool(interpret))}",
        f"schedule={schedule or 'plan'}",
        f"steps={'single' if steps is None else int(steps)}",
        f"plane_tile={'plan' if plane_tile is None else int(plane_tile)}",
        f"mesh={mesh_fingerprint(mesh, mesh_axes)}",
        f"schema={PLAN_SCHEMA_VERSION}",
    ])


# --------------------------------------------------------------------------
# Time-loop update-rule normalisation
# --------------------------------------------------------------------------

#: The accepted update-rule signatures, for error messages and docs.
UPDATE_SIGNATURES = ("update(fields, outputs)",
                     "update(fields, outputs, scalars)")


def adapt_update(update):
    """Normalise a time-loop update rule to ``fn(fields, outputs, scalars)``.

    This is the update-rule *contract* of every fused time loop
    (``compile_program(..., steps=N, update=...)``), on all backends, local
    and sharded.  Two forms are accepted:

    * ``update(fields, outputs) -> fields`` — the historical rule: maps the
      current persistent fields and this step's program outputs to the next
      step's fields (e.g. a forward-Euler ``u + dt * su``);
    * ``update(fields, outputs, scalars) -> fields`` — additionally receives
      the runtime scalars mapping, for rules that need traced values inside
      the loop (a traced ``dt``, the serving layer's bucket-size scalars).

    Every time-loop lowering routes the rule through here, so both
    signatures work everywhere.  Idempotent: adapting an already-adapted
    rule returns it unchanged.  A callable matching *neither* form — wrong
    arity for both — raises a :class:`TypeError` naming the accepted
    signatures here, at compile time, instead of a bare arity error from
    deep inside the traced loop body.
    """
    if update is None or getattr(update, "_takes_scalars", False):
        return update
    if not callable(update):
        raise TypeError(
            f"update rule must be callable, got {type(update).__name__}; "
            "accepted signatures: " + " or ".join(UPDATE_SIGNATURES))
    try:
        params = list(inspect.signature(update).parameters.values())
    except (TypeError, ValueError):
        params = None            # builtins/C callables: assume the 2-form
    if params is None:
        takes3 = False
    else:
        pos = [q for q in params if q.kind in (q.POSITIONAL_ONLY,
                                               q.POSITIONAL_OR_KEYWORD)]
        required = [q for q in pos if q.default is q.empty]
        var_pos = any(q.kind == q.VAR_POSITIONAL for q in params)
        # can the callable be invoked with exactly 2 / exactly 3 positional
        # arguments?  (keyword-only params with defaults don't matter)
        fits2 = len(required) <= 2 and (len(pos) >= 2 or var_pos)
        fits3 = len(required) <= 3 and (len(pos) >= 3 or var_pos)
        if not fits2 and not fits3:
            raise TypeError(
                f"update rule {getattr(update, '__name__', update)!r} takes "
                f"{len(required)} required positional argument(s); a fused "
                "time-loop update rule must accept one of: "
                + " or ".join(UPDATE_SIGNATURES))
        takes3 = fits3
    if takes3:
        def fn(fields, outputs, scalars, _u=update):
            return _u(fields, outputs, scalars)
    else:
        def fn(fields, outputs, scalars, _u=update):
            return _u(fields, outputs)
    fn._takes_scalars = True
    return fn


@dataclasses.dataclass
class ShardSpec:
    """Distributed layout of one compiled executable (paper step 9: one AXI
    bundle / HBM bank per field; here one mesh shard per sub-domain).

    Derived by :func:`make_shard_spec` from the plan's fuse groups: each
    field's halo depth is the elementwise max over every consuming group's
    window halo, so one carry-resident exchange per field per step serves
    all groups (they slice their own window geometry out of the exchanged
    buffer).  The planner prices blocks against ``local_grid``, never the
    global domain.
    """

    # mesh axis name per grid axis (None = unsharded axis)
    mesh_axes: tuple
    # mesh axis name -> number of shards along it
    axis_sizes: dict
    local_grid: tuple
    global_grid: tuple
    # field -> (ndim, 2) halo depth of the worst consuming fuse group
    field_halo: dict
    # the plan's stream axis (schedule="stream"; None for block plans).
    # When this axis is itself sharded, the per-shard sweep needs exact,
    # chain-deepened lo-side ghost planes (see dataflow.stream_halo) — the
    # field halos above already price them.
    stream_axis: int | None = None

    def axis_size(self, ax: int) -> int:
        name = self.mesh_axes[ax]
        return 1 if name is None else int(self.axis_sizes[name])

    @property
    def stream_sharded(self) -> bool:
        """True when the plan streams over an axis the mesh decomposes."""
        return (self.stream_axis is not None
                and self.axis_size(self.stream_axis) > 1)

    def describe(self) -> str:
        parts = []
        for ax, name in enumerate(self.mesh_axes):
            parts.append(f"{name or '-'}:{self.axis_size(ax)}")
        stream = ("" if self.stream_axis is None
                  else f", stream_axis={self.stream_axis}"
                       f"{'/sharded' if self.stream_sharded else ''}")
        return (f"shard(mesh=[{','.join(parts)}], local={self.local_grid}, "
                f"global={self.global_grid}{stream})")


def normalize_mesh_axes(mesh_axes: Sequence, ndim: int) -> tuple:
    """Mesh axis names truncated/padded to ``ndim`` entries (None = unsharded)
    — the one normalization every layer (pipeline, tuner, shard spec) uses."""
    ma = tuple(mesh_axes or ())
    return ma[:ndim] + (None,) * (ndim - len(ma))


def shard_local_grid(global_grid: Sequence[int], mesh, mesh_axes: Sequence
                     ) -> tuple:
    """Per-shard sub-domain extents; validates mesh/grid divisibility."""
    global_grid = tuple(int(g) for g in global_grid)
    out = []
    for ax, g in enumerate(global_grid):
        name = mesh_axes[ax] if ax < len(mesh_axes) else None
        n = 1 if name is None else int(mesh.shape[name])
        if g % n:
            raise ValueError(f"grid axis {ax} ({g}) not divisible by mesh "
                             f"axis {name!r} ({n})")
        out.append(g // n)
    return tuple(out)


def make_shard_spec(p: Program, plan: DataflowPlan, global_grid: Sequence[int],
                    mesh, mesh_axes: Sequence,
                    group_halos: list | None = None,
                    stream_axis: int | None = None) -> ShardSpec:
    """Build the :class:`ShardSpec` for ``plan`` over ``mesh``.

    Halo exchange is single-hop (each shard talks to its immediate
    neighbours), so a field's halo may not exceed the local extent of a
    sharded axis — violations raise here, at plan time, not inside the
    traced loop.  Pass ``group_halos`` (one :func:`infer_halo` result per
    fuse group, or the stream graph's chain-accumulated region halos) to
    reuse halos the caller already computed.  ``stream_axis`` records the
    plan's sweep axis for stream plans: sharding it is supported — the
    ``group_halos`` must then carry the deepened ghost-plane reach, and a
    sweep (plus temporal chain) too deep for the local block fails the
    single-hop check here with the mesh/time_tile levers named.
    """
    ndim = p.ndim
    mesh_axes = normalize_mesh_axes(mesh_axes, ndim)
    local_grid = shard_local_grid(global_grid, mesh, mesh_axes)
    if group_halos is None:
        group_halos = plan_group_halos(p, plan)
    field_halo = {}
    for gh in group_halos:
        for f in gh.group_inputs:
            cur = field_halo.get(f)
            field_halo[f] = (np.array(gh.input_halo) if cur is None
                             else np.maximum(cur, gh.input_halo))
    axis_sizes = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    for ax, name in enumerate(mesh_axes):
        if name is None or axis_sizes.get(str(name), 1) == 1:
            continue
        for f, h in field_halo.items():
            if max(int(h[ax, 0]), int(h[ax, 1])) > local_grid[ax]:
                lever = ("coarsen the mesh axis "
                         f"{name!r} or enlarge the grid")
                if ax == stream_axis:
                    lever = (f"coarsen the mesh axis {name!r}, shallow the "
                             "time_tile chain, or leave the stream axis "
                             "unsharded")
                raise ValueError(
                    f"halo of field {f!r} on axis {ax} "
                    f"({int(h[ax, 0])},{int(h[ax, 1])}) exceeds the local "
                    f"extent {local_grid[ax]}; {lever}")
    return ShardSpec(mesh_axes=mesh_axes, axis_sizes=axis_sizes,
                     local_grid=local_grid,
                     global_grid=tuple(int(g) for g in global_grid),
                     field_halo=field_halo, stream_axis=stream_axis)


@dataclasses.dataclass
class TimeLoopSpec:
    """Plan for a fused on-device time loop (the paper's device-resident
    inter-iteration dataflow, §3.3 step 3 applied to the *time* axis).

    The loop carry holds one persistent, halo-padded buffer per program
    input field; each step reads stencil windows straight out of the carry
    (no per-step ``jnp.pad``), and the traced update rule writes the new
    interior back in place.  Per fuse group, ``double_buffer`` assigns a
    front/back slot pair per persistent field: the group reads the front
    slot, the update writes the back slot, and parity swaps every step —
    the functional lowering realises the swap through XLA buffer donation
    on the loop carry.
    """

    steps: int
    # fields carried across steps (the program's external inputs)
    persistent: list
    # field -> (ndim, 2) carry padding [halo + tile alignment on the hi side]
    field_pad: dict
    # field -> (front_slot, back_slot) logical buffer ids
    double_buffer: dict
    # per fuse group: {field: (ndim,) int start offsets of the group's
    # expected window inside the carry buffer} (0 for transient inputs)
    group_offsets: list
    # how the loop body writes the back buffer:
    #   "repad"   — rebuild interior + constant zero halo in one fused write
    #               (zero-halo slabs are constants; fastest on XLA:CPU, which
    #               lowers the in-place form to a full read-modify-write)
    #   "inplace" — scatter the new interior into the carry
    #               (dynamic-update-slice; aliases on TPU)
    carry_write: str = "repad"
    # hi-side lane-tile alignment slab per axis, already folded into
    # field_pad[:, 1]; kept separately so halo refresh (periodic wrap,
    # distributed ppermute) can treat it as a plain zero slab
    align_hi: tuple = ()
    # distributed layout when the loop runs under shard_map; None = local.
    # With a shard, every extent in this spec is per-shard (local_grid).
    shard: ShardSpec | None = None

    def describe(self) -> str:
        bufs = ", ".join(f"{f}:{a}/{b}" for f, (a, b)
                         in self.double_buffer.items())
        return (f"time_loop(steps={self.steps}, "
                f"persistent=[{','.join(self.persistent)}], "
                f"double_buffer=[{bufs}])")


def plan_time_loop(p: Program, plan: DataflowPlan, grid: Sequence[int],
                   steps: int, carry_write: str = "repad",
                   group_halos: list | None = None,
                   shard: ShardSpec | None = None) -> TimeLoopSpec:
    """Size the carry buffers for a fused time loop.

    For the Pallas backend a field's carry padding is the elementwise max of
    the window halos of every fuse group consuming it, plus the lane-tile
    alignment padding on the hi side (so any group can slice its expected
    window geometry out of the carry without reallocating).  The jnp
    backends share the same spec minus alignment.

    With ``shard``, ``grid`` must be the shard's *local* grid and the spec
    describes the per-shard carry; the distributed executor refreshes the
    halo slabs by ``ppermute`` inside the loop body.
    """
    grid = tuple(int(g) for g in grid)
    ndim = p.ndim
    steps = int(steps)
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    persistent = p.input_fields()

    align_hi = np.zeros(ndim, dtype=np.int64)
    if plan.backend == "pallas" and plan.schedule != "stream":
        # mirror build_group_call's tile geometry exactly (the stream
        # schedule never tiles, so its carries carry no alignment slab)
        block = tuple(min(int(b), g) for b, g in zip(plan.block[:ndim], grid))
        tiles = tuple(-(-grid[a] // block[a]) for a in range(ndim))
        align_hi = np.asarray([tiles[a] * block[a] - grid[a]
                               for a in range(ndim)], dtype=np.int64)

    field_pad = {f: _zeros(ndim) for f in persistent}
    if group_halos is None:
        group_halos = plan_group_halos(p, plan)
    for gh in group_halos:
        for f in gh.group_inputs:
            if f in field_pad:
                field_pad[f] = np.maximum(field_pad[f], gh.input_halo)
    # the jnp lowerings evaluate every op (no DCE), so their carry must also
    # cover raw access offsets from ops outside the live fuse groups; the
    # pallas backend only runs the planned (live) groups, so widening its
    # carry for dead ops would over-allocate every persistent buffer
    if plan.backend != "pallas":
        for op in p.ops:
            for a in op.accesses():
                m = field_pad.get(a.field)
                if m is None:
                    continue
                for ax in range(ndim):
                    o = int(a.offset[ax])
                    m[ax, 0] = max(m[ax, 0], -o)
                    m[ax, 1] = max(m[ax, 1], o)
    for f in persistent:
        field_pad[f][:, 1] += align_hi

    double_buffer = {f: (2 * i, 2 * i + 1) for i, f in enumerate(persistent)}
    group_offsets = []
    for gh in group_halos:
        offs = {}
        for f in gh.group_inputs:
            if f in field_pad:
                offs[f] = tuple(int(field_pad[f][a, 0] - gh.input_halo[a, 0])
                                for a in range(ndim))
            else:
                offs[f] = (0,) * ndim
        group_offsets.append(offs)
    if carry_write not in ("repad", "inplace"):
        raise ValueError(f"unknown carry_write {carry_write!r}")
    return TimeLoopSpec(steps=steps, persistent=persistent,
                        field_pad=field_pad, double_buffer=double_buffer,
                        group_offsets=group_offsets, carry_write=carry_write,
                        align_hi=tuple(int(a) for a in align_hi),
                        shard=shard)


def plan_group_halos(p: Program, plan: DataflowPlan,
                     stream_sharded: bool = False) -> list:
    """One :class:`~repro.core.passes.GroupHalo` per executed kernel of
    ``plan`` — block-schedule fuse groups via :func:`infer_halo`, stream
    regions (post-legalisation, with shift-register stream-axis halos, and
    reach accumulated over the chained steps when ``time_tile > 1``) via
    the dataflow layer.  ``stream_sharded`` deepens the stream-axis lo
    halos for a mesh that decomposes the sweep axis.  Every carry/shard
    sizing goes through here so the padding always matches what the
    lowered kernels will slice."""
    if plan.schedule == "stream":
        from .dataflow import lower_to_dataflow
        return lower_to_dataflow(
            p, plan, stream_sharded=stream_sharded).group_halos()
    return [infer_halo(p, grp) for grp in plan.groups]


def _dtype_bytes(dtype: str) -> int:
    return hw.DTYPE_BYTES[dtype]


def vmem_cost(p: Program, plan: DataflowPlan, grid: Sequence[int],
              steps: int | None = None, graph=None) -> int:
    """Bytes of VMEM one kernel instance of the *largest* group claims.

    window bytes x live inputs + margin-extended temps + output tiles,
    times 2 for the Pallas double-buffered pipeline.

    With ``steps`` (fused time loop), persistent inputs are windows sliced
    out of the loop *carry*, whose padding — the max halo over every
    consuming group plus the lane-tile ``align_hi`` slab sized by
    :func:`plan_time_loop` — can exceed this group's own halo, enlarging the
    window the ``input_pad`` path claims.  A plan that fits the budget
    single-step can therefore exceed it under ``steps=N``; the tuner prunes
    with this corrected cost.
    """
    bs = _dtype_bytes(plan.dtype)
    grid = tuple(int(g) for g in grid)
    if plan.schedule == "stream":
        return _vmem_cost_stream(p, plan, grid, bs, graph=graph)
    group_halos = [infer_halo(p, grp) for grp in plan.groups]
    carry_pad = (plan_time_loop(p, plan, grid, steps,
                                group_halos=group_halos).field_pad
                 if steps is not None else {})
    worst = 0
    for grp, gh in zip(plan.groups, group_halos):
        blk = np.minimum(np.asarray(plan.block[:p.ndim]), np.asarray(grid))
        total = 0
        for f in gh.group_inputs:
            pad = gh.input_halo
            if f in carry_pad:
                pad = np.maximum(pad, carry_pad[f])
            win = blk + pad[:, 0] + pad[:, 1]
            total += int(np.prod(win)) * bs
        for i in grp:
            m = gh.margins[i]
            ext = blk + m[:, 0] + m[:, 1]
            total += int(np.prod(ext)) * bs
        worst = max(worst, total)
    return 2 * worst  # double buffering


def _vmem_cost_stream(p: Program, plan: DataflowPlan, grid: tuple,
                      bs: int, graph=None) -> int:
    """VMEM one stream region claims: the rolling window buffers (depth x
    padded plane per input), temp ring buffers, one margin-extended result
    plane per op, and the output planes in flight.  Unlike the block path
    there is no tile geometry — the non-stream axes are resident whole, so
    a carry's ``input_pad`` slicing never enlarges the kernel windows.

    With temporal blocking (effective ``time_tile = T > 1``) the chained
    kernel claims strictly more scratch, and the tuner's pruning must see
    it: the external plane buffers widen to the T-fold accumulated halo,
    every later chain stage keeps a window-depth ring of each persistent
    field at its own (shrinking) stage extent, and each stage's op planes
    carry the stage's accumulated margin.  Pricing only the T=1 geometry
    here would admit chained plans that overflow scratch at run time.

    With spatial unrolling (effective ``plane_tile = P > 1``) each sweep
    grid step stages a P-plane DMA block next to every window buffer
    (``depth + P`` planes live during the shift) and the output side holds
    the P-plane out block plus the up-to-``P-1``-plane staging ring that
    realigns completed planes to the block grid.
    """
    if graph is None:
        from .dataflow import lower_to_dataflow
        graph = lower_to_dataflow(p, plan)
    ndim = p.ndim
    T = getattr(graph, "time_tile", 1)
    P = getattr(graph, "plane_tile", 1)
    worst = 0
    for region in graph.regions:
        gh = region.halo
        hl = [int(gh.input_halo[a, 0]) for a in range(ndim)]
        hh = [int(gh.input_halo[a, 1]) for a in range(ndim)]
        # stage-s working extent on a non-stream axis: grid + margins +
        # (T-1-s) accumulated halo steps; stage 0 reads the full T-fold
        # padded external planes (plus the P-plane DMA block mid-shift)
        plane = [grid[a] + T * (hl[a] + hh[a]) for a in range(1, ndim)]
        total = 0
        for f in gh.group_inputs:
            total += (region.depths[f] + P) * int(np.prod(plane)) * bs
        for s in range(1, T):
            ext_s = [grid[a] + (T - s) * (hl[a] + hh[a])
                     for a in range(1, ndim)]
            for f in gh.group_inputs:
                total += region.depths[f] * int(np.prod(ext_s)) * bs
        for s in range(T):
            acc = T - 1 - s
            for i in region.ops:
                m = gh.margins[i]
                ext = [grid[a] + int(m[a, 0]) + int(m[a, 1])
                       + acc * (hl[a] + hh[a]) for a in range(1, ndim)]
                planes = 1 + region.rings.get(p.ops[i].out, 0)
                total += planes * int(np.prod(ext)) * bs
        out_planes = P + (P - 1 if P > 1 else 0)
        total += (len(gh.group_outputs) * out_planes
                  * int(np.prod(grid[1:])) * bs)
        worst = max(worst, total)
    return 2 * worst  # double-buffered pipeline, as in the block schedule


def auto_plan(p: Program, grid: Sequence[int], *, backend: str = "pallas",
              interpret: bool = True, strategy: str = "auto",
              dtype: str = "float32",
              vmem_budget: int = hw.VMEM_PLAN_BUDGET,
              steps: int | None = None,
              schedule: str = "block",
              time_tile: int = 1,
              plane_tile: int = 1) -> DataflowPlan:
    """Pick fuse groups and a lane-aligned block shape that fits VMEM.

    Mirrors the paper's auto-optimisation: the planner, not the programmer,
    chooses the dataflow structure.  Last axis is lane-aligned to 128
    (the 512-bit-burst analogue); the remaining axes shrink first.

    With ``steps`` (the plan will drive a fused time loop) the budget check
    uses the carry-aware :func:`vmem_cost`, so blocks whose loop-carry
    padding enlarges the kernel windows past the budget are shrunk here
    rather than discovered over budget at run time.
    """
    grid = tuple(int(g) for g in grid)
    ndim = p.ndim
    groups = stage_split(p, strategy)
    if schedule == "stream":
        return _auto_plan_stream(p, grid, groups, backend=backend,
                                 interpret=interpret, dtype=dtype,
                                 vmem_budget=vmem_budget,
                                 time_tile=time_tile,
                                 plane_tile=plane_tile)
    if time_tile > 1:
        raise ValueError("time_tile > 1 requires schedule='stream' "
                         "(temporal blocking chains the stream sweep)")
    if plane_tile > 1:
        raise ValueError("plane_tile > 1 requires schedule='stream' "
                         "(spatial unrolling widens the stream sweep)")

    # start from a generous tile and shrink to fit the budget
    blk = []
    for ax in range(ndim):
        if ax == ndim - 1:  # lane axis: multiples of 128, at least 128
            blk.append(min(grid[ax], max(hw.LANE, hw.align_down(grid[ax], hw.LANE))))
        else:
            blk.append(min(grid[ax], 32 if ndim == 3 else 256))
    blk = [max(1, b) for b in blk]

    def fits(b):
        plan = DataflowPlan(groups=groups, block=tuple(b), dtype=dtype,
                            backend=backend, interpret=interpret,
                            mesh_axes=(None,) * ndim)
        return vmem_cost(p, plan, grid, steps=steps) <= vmem_budget

    # shrink non-lane axes first, then the lane axis (keep 128 quanta)
    guard = 0
    while not fits(blk) and guard < 64:
        guard += 1
        order = list(range(ndim - 1)) + [ndim - 1]
        shrunk = False
        for ax in order:
            quantum = hw.LANE if ax == ndim - 1 else 1
            if blk[ax] > quantum:
                blk[ax] = max(quantum, blk[ax] // 2)
                shrunk = True
                break
        if not shrunk:
            # cannot shrink further: split groups per field instead
            if any(len(g) > 1 for g in groups):
                groups = stage_split(p, "per_field")
            else:
                break
    return DataflowPlan(groups=groups, block=tuple(blk), dtype=dtype,
                        backend=backend, interpret=interpret,
                        mesh_axes=(None,) * ndim)


def _auto_plan_stream(p: Program, grid: tuple, groups: list, *,
                      backend: str, interpret: bool, dtype: str,
                      vmem_budget: int, time_tile: int = 1,
                      plane_tile: int = 1) -> DataflowPlan:
    """Stream-scheduled plan: one rolling-window sweep over the outer axis
    per (legalised) region, non-stream axes resident whole.  The ``block``
    field records the degenerate one-plane tile for display/cost purposes.
    If the full-slab window buffers blow the VMEM budget the levers are,
    in order: a narrower plane unroll (``plane_tile`` halves toward 1),
    then a shallower temporal chain (``time_tile`` halves toward 1),
    then a finer region split (intermediates stream through HBM)."""
    if backend != "pallas":
        raise ValueError(
            f"schedule='stream' is a pallas dataflow schedule; backend "
            f"{backend!r} has no streaming lowering")
    from .dataflow import lower_to_dataflow
    ndim = p.ndim
    block = (1,) + grid[1:]

    def build(groups, tile, ptile):
        plan = DataflowPlan(groups=groups, block=block, dtype=dtype,
                            backend=backend, interpret=interpret,
                            mesh_axes=(None,) * ndim, schedule="stream",
                            time_tile=tile, plane_tile=ptile)
        graph = lower_to_dataflow(p, plan, grid)
        plan.stream = graph.spec()
        return plan, graph

    tile = max(1, int(time_tile))
    ptile = max(1, int(plane_tile))
    plan, graph = build(groups, tile, ptile)
    while (vmem_cost(p, plan, grid, graph=graph) > vmem_budget
           and ptile > 1):
        ptile //= 2              # P-plane blocks too wide: narrower unroll
        plan, graph = build(groups, tile, ptile)
    while (vmem_cost(p, plan, grid, graph=graph) > vmem_budget
           and tile > 1):
        tile //= 2               # chained buffers too deep: shallower chain
        plan, graph = build(groups, tile, ptile)
    if (vmem_cost(p, plan, grid, graph=graph) > vmem_budget
            and any(len(g) > 1 for g in groups)):
        plan, _ = build(stage_split(p, "per_field"), tile, ptile)
    return plan
