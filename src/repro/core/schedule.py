"""DataflowPlan — the HLS-dialect analogue (paper §3.1).

Where the paper's HLS dialect records FPGA decisions (streams, pipeline II,
unroll, array_partition, AXI bundles), the plan records their TPU analogues:

  hls.create_stream / dataflow  ->  fuse-group boundaries + Pallas pipeline
  hls.pipeline(II)              ->  grid/block shape (VMEM tiling)
  hls.unroll                    ->  in-tile vectorisation (VPU lanes; implicit)
  hls.array_partition           ->  window layout (halo), lane alignment
  hls.interface / bundles       ->  PartitionSpec per field (chips = banks)

A plan is pure data: both backends and the distributed executor consume it,
and the hillclimb loop mutates it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .. import hw
from .ir import Program
from .passes import infer_halo, stage_split


@dataclasses.dataclass
class DataflowPlan:
    # fuse groups: ordered list of lists of op indices
    groups: list
    # output tile shape per axis (the VMEM block)
    block: tuple
    # dtype for field storage/compute
    dtype: str = "float32"
    # backend: "pallas" | "jnp_fused" | "jnp_naive"
    backend: str = "pallas"
    # run pallas in interpret mode (CPU container) — real runs set False
    interpret: bool = True
    # distributed layout: mesh axis name per grid axis (None = unsharded)
    mesh_axes: tuple = (None, None, None)
    # exchange halos every k steps with k-wide halos (comm amortisation)
    halo_every: int = 1

    def describe(self) -> str:
        g = ", ".join("{" + ",".join(map(str, grp)) + "}" for grp in self.groups)
        return (f"plan(groups=[{g}], block={self.block}, backend={self.backend}, "
                f"mesh_axes={self.mesh_axes})")


def _dtype_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float64": 8}[dtype]


def vmem_cost(p: Program, plan: DataflowPlan, grid: Sequence[int]) -> int:
    """Bytes of VMEM one kernel instance of the *largest* group claims.

    window bytes x live inputs + margin-extended temps + output tiles,
    times 2 for the Pallas double-buffered pipeline.
    """
    bs = _dtype_bytes(plan.dtype)
    worst = 0
    for grp in plan.groups:
        gh = infer_halo(p, grp)
        blk = np.minimum(np.asarray(plan.block[:p.ndim]), np.asarray(grid))
        win = blk + gh.input_halo[:, 0] + gh.input_halo[:, 1]
        total = int(np.prod(win)) * len(gh.group_inputs) * bs
        for i in grp:
            m = gh.margins[i]
            ext = blk + m[:, 0] + m[:, 1]
            total += int(np.prod(ext)) * bs
        worst = max(worst, total)
    return 2 * worst  # double buffering


def auto_plan(p: Program, grid: Sequence[int], *, backend: str = "pallas",
              interpret: bool = True, strategy: str = "auto",
              dtype: str = "float32",
              vmem_budget: int = hw.VMEM_PLAN_BUDGET) -> DataflowPlan:
    """Pick fuse groups and a lane-aligned block shape that fits VMEM.

    Mirrors the paper's auto-optimisation: the planner, not the programmer,
    chooses the dataflow structure.  Last axis is lane-aligned to 128
    (the 512-bit-burst analogue); the remaining axes shrink first.
    """
    grid = tuple(int(g) for g in grid)
    ndim = p.ndim
    groups = stage_split(p, strategy)

    # start from a generous tile and shrink to fit the budget
    blk = []
    for ax in range(ndim):
        if ax == ndim - 1:  # lane axis: multiples of 128, at least 128
            blk.append(min(grid[ax], max(hw.LANE, hw.align_down(grid[ax], hw.LANE))))
        else:
            blk.append(min(grid[ax], 32 if ndim == 3 else 256))
    blk = [max(1, b) for b in blk]

    def fits(b):
        plan = DataflowPlan(groups=groups, block=tuple(b), dtype=dtype,
                            backend=backend, interpret=interpret)
        return vmem_cost(p, plan, grid) <= vmem_budget

    # shrink non-lane axes first, then the lane axis (keep 128 quanta)
    guard = 0
    while not fits(blk) and guard < 64:
        guard += 1
        order = list(range(ndim - 1)) + [ndim - 1]
        shrunk = False
        for ax in order:
            quantum = hw.LANE if ax == ndim - 1 else 1
            if blk[ax] > quantum:
                blk[ax] = max(quantum, blk[ax] // 2)
                shrunk = True
                break
        if not shrunk:
            # cannot shrink further: split groups per field instead
            if any(len(g) > 1 for g in groups):
                groups = stage_split(p, "per_field")
            else:
                break
    return DataflowPlan(groups=groups, block=tuple(blk), dtype=dtype,
                        backend=backend, interpret=interpret)
