"""Shared expression-tree evaluator.

Both backends (full-array jnp and in-kernel Pallas) evaluate the same IR by
supplying an *access resolver*; hash-consing of the frozen Expr nodes gives
CSE for free via the memo table (tracer advection's 24 ops share many
subtrees).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .ir import (Access, BinOp, BinOpKind, Cmp, CmpKind, CoeffRef, Const,
                 Expr, ScalarRef, Select, UnOp, UnOpKind)

_BIN = {
    BinOpKind.ADD: lambda a, b: a + b,
    BinOpKind.SUB: lambda a, b: a - b,
    BinOpKind.MUL: lambda a, b: a * b,
    BinOpKind.DIV: lambda a, b: a / b,
    BinOpKind.POW: lambda a, b: a ** b,
    BinOpKind.MIN: jnp.minimum,
    BinOpKind.MAX: jnp.maximum,
}
_UN = {
    UnOpKind.NEG: lambda a: -a,
    UnOpKind.ABS: jnp.abs,
    UnOpKind.SQRT: jnp.sqrt,
    UnOpKind.EXP: jnp.exp,
    UnOpKind.LOG: jnp.log,
    UnOpKind.TANH: jnp.tanh,
    UnOpKind.SQUARE: jnp.square,
    UnOpKind.SIGN: jnp.sign,
}
_CMP = {
    CmpKind.LT: lambda a, b: a < b,
    CmpKind.LE: lambda a, b: a <= b,
    CmpKind.GT: lambda a, b: a > b,
    CmpKind.GE: lambda a, b: a >= b,
    CmpKind.EQ: lambda a, b: a == b,
}


def evaluate(expr: Expr, access: Callable[[Access], jnp.ndarray],
             scalar: Callable[[str], jnp.ndarray], memo: dict | None = None,
             coeff: Callable[[CoeffRef], jnp.ndarray] | None = None):
    """Evaluate ``expr``; ``access`` resolves Access nodes, ``scalar`` names,
    ``coeff`` CoeffRef nodes (broadcastable 1-D coefficient reads)."""
    if memo is None:
        memo = {}

    def rec(e: Expr):
        hit = memo.get(e)
        if hit is not None:
            return hit
        if isinstance(e, Const):
            r = e.value
        elif isinstance(e, ScalarRef):
            r = scalar(e.name)
        elif isinstance(e, CoeffRef):
            if coeff is None:
                raise ValueError("program uses coefficients but no resolver given")
            r = coeff(e)
        elif isinstance(e, Access):
            r = access(e)
        elif isinstance(e, BinOp):
            r = _BIN[e.kind](rec(e.lhs), rec(e.rhs))
        elif isinstance(e, UnOp):
            r = _UN[e.kind](rec(e.operand))
        elif isinstance(e, Cmp):
            r = _CMP[e.kind](rec(e.lhs), rec(e.rhs))
        elif isinstance(e, Select):
            r = jnp.where(rec(e.pred), rec(e.on_true), rec(e.on_false))
        else:
            raise TypeError(type(e))
        memo[e] = r
        return r

    return rec(expr)
