"""jnp backends — the Von-Neumann reference lowerings.

Two variants, playing the roles of the paper's baselines:

* ``naive`` — each op traverses full arrays independently, every access is a
  fresh zero-padded shift (the role of unoptimised Vitis HLS / -O0: correct
  by construction, no reuse structure).
* ``fused`` — ops evaluated with one shared memo across the whole program,
  so repeated subtrees and repeated accesses evaluate once and XLA fuses the
  elementwise graph (the role DaCe plays in the paper: an optimising but
  non-stencil-specialised pipeline).

Both are also the *oracles* against which the Pallas backend is verified.
SSA discipline (every field written exactly once, enforced by the builder)
makes the shared memo sound: an Access never goes stale.
"""

from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp
import numpy as np

from .expr_eval import evaluate
from .ir import Access, FieldRole, Program


def shifted(x: jnp.ndarray, offset, pad_value: float = 0.0) -> jnp.ndarray:
    """out[i] = x[i + offset], reading 0 outside the domain."""
    h = int(max(abs(int(o)) for o in offset)) if len(offset) else 0
    if h == 0 and all(int(o) == 0 for o in offset):
        return x
    xp = jnp.pad(x, h, constant_values=pad_value)
    idx = tuple(slice(h + int(offset[ax]), h + int(offset[ax]) + x.shape[ax])
                for ax in range(x.ndim))
    return xp[idx]


def lower(p: Program, mode: str = "fused"):
    """Return fn(fields, scalars) -> dict of output arrays."""
    if mode not in ("naive", "fused"):
        raise ValueError(mode)

    def run(fields: Mapping[str, jnp.ndarray],
            scalars: Mapping[str, jnp.ndarray] | None = None,
            coeffs: Mapping[str, jnp.ndarray] | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        env = dict(fields)
        outputs = {}
        shared_memo: dict = {}
        any_field = next(iter(fields.values()))

        def coeff(c):
            ax = p.coeffs[c.coeff]
            v = shifted(coeffs[c.coeff], (c.offset,))
            shape = [1] * p.ndim
            shape[ax] = v.shape[0]
            return v.reshape(shape)

        for op in p.ops:
            memo = shared_memo if mode == "fused" else {}

            def access(a: Access):
                return shifted(env[a.field], a.offset)

            res = evaluate(op.expr, access, lambda n: scalars[n], memo,
                           coeff=coeff)
            res = jnp.broadcast_to(res, any_field.shape)
            env[op.out] = res
            if p.fields[op.out].role == FieldRole.OUTPUT:
                outputs[op.out] = res
        return outputs

    return run
