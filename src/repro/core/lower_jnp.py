"""jnp backends — the Von-Neumann reference lowerings.

Two variants, playing the roles of the paper's baselines:

* ``naive`` — each op traverses full arrays independently, every access is a
  fresh zero-padded shift (the role of unoptimised Vitis HLS / -O0: correct
  by construction, no reuse structure).
* ``fused`` — ops evaluated with one shared memo across the whole program,
  so repeated subtrees and repeated accesses evaluate once and XLA fuses the
  elementwise graph (the role DaCe plays in the paper: an optimising but
  non-stencil-specialised pipeline).

Both are also the *oracles* against which the Pallas backend is verified.
SSA discipline (every field written exactly once, enforced by the builder)
makes the shared memo sound: an Access never goes stale.
"""

from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp

from . import boundary as bc
from .expr_eval import evaluate
from .ir import Access, FieldRole, Program


def lower(p: Program, mode: str = "fused", prepad: Mapping | None = None,
          shift_fn=None, coeff_fn=None):
    """Return fn(fields, scalars) -> dict of output arrays.

    With ``prepad`` (field name -> (ndim, 2) halo widths) the external input
    fields must arrive *already padded* by those amounts (halo slabs filled
    per the field's boundary by the caller); every Access then resolves to a
    static slice of the persistent padded buffer instead of a fresh pad —
    the access path the fused time loop uses for its carry-resident fields.
    Temps produced mid-program stay interior-shaped and keep the
    shift-on-access path, which honours each field's declared boundary
    (zero extension or torus wraparound).

    ``shift_fn(x, offset, boundary)`` overrides the shift-on-access path and
    ``coeff_fn(cref, coeffs)`` the coefficient read — the hooks the
    distributed executor uses to route accesses through ``ppermute`` and to
    slice replicated coefficient arrays at the shard origin.
    """
    if mode not in ("naive", "fused"):
        raise ValueError(mode)
    prepadded = set(prepad or {})
    bnd = p.boundaries()
    cmode = bc.coeff_mode(p)
    shift = shift_fn or bc.shift_field

    def run(fields: Mapping[str, jnp.ndarray],
            scalars: Mapping[str, jnp.ndarray] | None = None,
            coeffs: Mapping[str, jnp.ndarray] | None = None):
        scalars = scalars or {}
        coeffs = coeffs or {}
        env = dict(fields)
        outputs = {}
        shared_memo: dict = {}
        any_field = next(iter(fields.values()))
        if prepad is None:
            interior = any_field.shape
        else:
            fref = next(f for f in fields if f in prepadded)
            h = prepad[fref]
            interior = tuple(fields[fref].shape[ax]
                             - int(h[ax, 0]) - int(h[ax, 1])
                             for ax in range(p.ndim))

        def coeff(c):
            if coeff_fn is not None:
                return coeff_fn(c, coeffs)
            ax = p.coeffs[c.coeff]
            v = bc.shift_field(coeffs[c.coeff], (c.offset,), cmode)
            shape = [1] * p.ndim
            shape[ax] = v.shape[0]
            return v.reshape(shape)

        for op in p.ops:
            memo = shared_memo if mode == "fused" else {}

            def access(a: Access):
                if a.field in prepadded:
                    h = prepad[a.field]
                    sl = tuple(slice(int(h[ax, 0]) + int(a.offset[ax]),
                                     int(h[ax, 0]) + int(a.offset[ax])
                                     + interior[ax])
                               for ax in range(p.ndim))
                    return env[a.field][sl]
                return shift(env[a.field], a.offset, bnd[a.field])

            res = evaluate(op.expr, access, lambda n: scalars[n], memo,
                           coeff=coeff)
            res = jnp.broadcast_to(res, interior)
            env[op.out] = res
            if p.fields[op.out].role == FieldRole.OUTPUT:
                outputs[op.out] = res
        return outputs

    return run


def lower_time_loop(p: Program, mode: str, spec, update):
    """Return fn(fields, scalars, coeffs) -> final fields after
    ``spec.steps`` fused iterations (single compiled program).

    Mirrors the Pallas fused loop: the ``lax.fori_loop`` carry holds the
    persistent input fields pre-padded by ``spec.field_pad``; every step the
    step body reads windows out of the carry (static slices, no ``jnp.pad``)
    and the traced ``update(fields, outputs)`` writes the new interiors back
    in place.  Halo slabs follow each field's boundary: zero slabs stay
    zero throughout; periodic slabs are rebuilt from the new interior every
    step (the wraparound values change with it).
    """
    import jax

    from .schedule import adapt_update

    update = adapt_update(update)
    fpad = spec.field_pad
    bnd = p.boundaries()
    step_fn = lower(p, mode, prepad=fpad)

    def run(fields: Mapping, scalars: Mapping | None = None,
            coeffs: Mapping | None = None):
        scalars = dict(scalars or {})
        coeffs = dict(coeffs or {})
        ndim = p.ndim
        shape = next(iter(fields.values())).shape
        interior = {f: tuple(slice(int(fpad[f][a, 0]),
                                   int(fpad[f][a, 0]) + shape[a])
                             for a in range(ndim))
                    for f in spec.persistent}

        def refill(f, x):
            return bc.pad_field(x, fpad[f][:, 0], fpad[f][:, 1], bnd[f])

        carry = {f: refill(f, jnp.asarray(fields[f]))
                 for f in spec.persistent}

        def body(_, carry):
            outs = step_fn(carry, scalars, coeffs)
            cur = {f: carry[f][interior[f]] for f in spec.persistent}
            new = dict(cur)
            new.update(update(cur, outs, scalars))
            out = {}
            for f in spec.persistent:
                if spec.carry_write == "inplace" and bnd[f] == "zero":
                    # zero halos never change: scatter the interior only
                    out[f] = carry[f].at[interior[f]].set(new[f])
                else:
                    # one fused interior write + constant (zero) or
                    # refreshed (wraparound) halo slabs
                    out[f] = refill(f, new[f])
            return out

        carry = jax.lax.fori_loop(0, spec.steps, body, carry)
        return {f: carry[f][interior[f]] for f in spec.persistent}

    return run
