"""Stream backend: StreamGraph regions -> shift-register Pallas kernels.

This is the hardware materialisation of the dataflow layer
(:mod:`repro.core.dataflow`), the role the paper's HLS dialect plays for the
FPGA backends.  Per region, one ``pl.pallas_call`` whose **grid iterates
over the outer (stream) axis**, one step per plane:

* each external input field is DMA'd as exactly **one new plane per step**
  (BlockSpec of depth 1) — each input element is fetched from HBM once per
  sweep;
* the shift-register window buffers live in VMEM **scratch that persists
  across grid steps** (the kernel's carry): every step rolls each buffer
  one plane and appends the new plane, so the full stencil window along the
  stream axis is always resident without refetching (paper Fig. 2);
* in-region temps consumed at *past* planes keep a small ring buffer of
  their own recent planes — stream-axis dependencies cost storage, never
  recompute;
* the output plane trails the stream front by the region's lead: the output
  BlockSpec's index map clamps ``step - (lo+hi)`` so warm-up steps write
  (and later overwrite) plane 0, and every plane's final value is computed
  from a full window.

Boundary handling mirrors the block schedule: the orchestrator pre-pads the
stream axis (zero slabs or torus wraparound planes), non-stream margins are
masked against the global domain for zero-boundary fields, and ring-buffered
temps store zeros for out-of-domain planes.

The produced callables expose the same geometry attributes as
``kernels.stencil3d.build_group_call`` (``group_inputs``/``pad_lo``/
``input_pad`` slicing/…), so the generic orchestrators in
:mod:`repro.core.lower_pallas` — including the fused ``lax.fori_loop`` time
loop with carry-resident persistent fields — drive stream and block kernels
identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..obs.trace import current_tracer
from .dataflow import StreamGraph, StreamRegion, lower_to_dataflow
from .expr_eval import evaluate
from .ir import Access, Program
from .lower_pallas import _DTYPES, lower_from_calls, time_loop_from_calls
from .schedule import DataflowPlan, TimeLoopSpec, adapt_update


def build_stream_call(p: Program, region: StreamRegion, grid_shape,
                      dtype=jnp.float32, interpret: bool = True,
                      global_extent=None, time_tile: int = 1, update=None,
                      stream_sharded: bool = False, plane_tile: int = 1):
    """Build a callable(padded_inputs, scalars, coeffs, origin) -> outputs
    streaming one region over the outer axis (see module docstring).

    ``padded_inputs`` must be padded by ``pad_lo``/``pad_hi`` (exposed on
    the returned callable); oversized persistent buffers ride in via the
    ``input_pad`` path exactly as for block kernels.

    With ``plane_tile = P > 1`` (spatial unrolling, the paper's parallel
    processing elements) each sweep grid step DMAs a **P-plane input
    block**, replays the single-plane pipeline for P consecutive *virtual*
    steps ``t = j*P .. j*P+P-1`` (all masking, ring and coefficient
    indexing keyed off ``t``, so per-plane semantics are bit-identical),
    shifts every window buffer by P planes at once, and stores all P
    completed output planes.  The sweep grid shrinks to
    ``ceil(n0/P) + ceil(span/P)`` steps: the input is rounded up with
    zero planes whose garbage outputs land past the domain and are sliced
    off, and when the warm-up span is not a P-multiple an ``r``-plane
    staging ring realigns completed planes to the P-plane output blocks
    (a trailing remainder therefore needs no separate shallow-tile
    epilogue kernel — scratch could not persist across calls anyway).

    With ``update`` (the already-normalised fused-loop rule) the kernel
    chains ``time_tile = T`` timestep *stages* per sweep step and returns
    the **updated persistent fields after T steps** instead of the stencil
    outputs: stage ``s`` completes interior plane ``c_s = t - lo -
    (s+1)*lead`` at sweep step ``t`` (each stage trails the previous by the
    region's stream lead), the update rule is applied plane-wise after
    every stage, and each later stage reads the *updated* fields out of
    per-stage VMEM rings instead of HBM — one plane fetched from HBM per T
    time steps.  Non-stream margins accumulate one halo step per remaining
    stage, so inputs arrive padded T-fold and stage extents shrink back to
    the grid by stage T-1, whose updated planes are stored.  The chain
    assumes an *element-wise* update rule (the fused-loop contract): it is
    applied per plane at each stage's working extent.

    ``stream_sharded`` marks the stream axis as domain-decomposed: the
    caller (the SPMD orchestrator) then pads the lo side of the stream axis
    with *exact* neighbour ghost planes — ``T x`` the region's (already
    ring-deepened) per-step lo halo, mirroring :func:`~repro.core.dataflow.
    chained_halo` — so every chain stage warms up on true values before the
    shard's first owned plane.  ``region.halo`` must come from a graph
    lowered with the same flag.  Unsharded sweeps keep the shallow lo pad;
    a 1x1 mesh therefore traces the identical kernel to a local compile.
    """
    ndim = p.ndim
    gh = region.halo
    T = max(1, int(time_tile))
    if T > 1 and update is None:
        raise ValueError("time_tile > 1 chains timestep stages in-kernel "
                         "and needs the fused-loop update rule")
    grid_shape = tuple(int(g) for g in grid_shape)
    if global_extent is None:
        global_extent = grid_shape
    global_extent = tuple(int(g) for g in global_extent)
    n0 = grid_shape[0]
    # per-step region halo (hl/hh) vs the T-chained outer padding (halo_lo/
    # halo_hi = what the caller pads: stream (lo, T*lead), non-stream T-fold)
    hl = tuple(int(gh.input_halo[a, 0]) for a in range(ndim))
    hh = tuple(int(gh.input_halo[a, 1]) for a in range(ndim))
    lead = hh[0]
    # lo-side stream pad: shallow locally (warm-up planes are masked
    # out-of-domain), chain-deepened exact ghosts under a sharded axis
    halo_lo = ((T * hl[0]) if stream_sharded else hl[0],) \
        + tuple(T * hl[a] for a in range(1, ndim))
    halo_hi = (T * lead,) + tuple(T * hh[a] for a in range(1, ndim))
    span = halo_lo[0] + halo_hi[0]    # stream reach of the whole chain
    n_steps = n0 + span               # padded planes = one *virtual* step each
    # spatial unrolling: P virtual steps per sweep grid step
    P = max(1, int(plane_tile))
    if P > n0:
        raise ValueError(
            f"plane_tile {P} exceeds the stream extent {n0}; "
            "dataflow.plane_split_reason should have demoted it")
    n_out = -(-n0 // P)          # P-plane output blocks
    K = -(-span // P)            # warm-up grid steps before block 0 is final
    stage_r = K * P - span       # staging planes realigning output to blocks
    n_tiles = n_out + K          # sweep grid steps
    pad_round = n_tiles * P - n_steps   # hi-side zero planes rounding the DMA
    # padded plane extents on the non-stream axes (group-uniform halo)
    plane_ext = tuple(grid_shape[a] + halo_lo[a] + halo_hi[a]
                      for a in range(1, ndim))
    # margin every remaining chain stage adds on the non-stream axes
    stage_add = np.zeros((ndim, 2), dtype=np.int64)
    for a in range(1, ndim):
        stage_add[a] = (hl[a], hh[a])

    ops = [p.ops[i] for i in region.ops]
    margins = {p.ops[i].out: gh.margins[i] for i in region.ops}
    produced = {op.out for op in ops}
    out_names = [op.out for op in ops if op.out in set(gh.group_outputs)]
    # with an update rule the sweep advances time in-kernel and the stored
    # arrays are the updated persistent fields, not the stencil outputs
    store_names = list(gh.group_inputs) if update is not None else out_names
    coeff_axis = {c: p.coeffs[c] for c in gh.group_coeffs}
    depths = {f: int(region.depths[f]) for f in gh.group_inputs}
    ring_depth = {t: int(r) for t, r in region.rings.items()}
    ring_names = [op.out for op in ops if op.out in ring_depth]
    n_scalars = len(p.scalars)
    scalar_index = {s: i for i, s in enumerate(p.scalars)}
    # stage s evaluates every op at its base margin plus (T-1-s) accumulated
    # halo steps (chained stages shrink back toward the grid); masking of a
    # stage's results follows the *stage* margins — non-stream recompute
    # needs the zero-halo mask unless the field is periodic (wrapped planes
    # are exact); the stream axis itself is handled by input padding + ring-
    # store masking, never here
    stage_margins = [{out: m + (T - 1 - s) * stage_add
                      for out, m in margins.items()} for s in range(T)]
    # per-(stage, field) ring-plane extents: stage s reads updated fields
    # padded by (T-s) halo steps, exactly what stage s-1's update produced
    ring_plane_ext = [tuple(grid_shape[a] + (T - s) * (hl[a] + hh[a])
                            for a in range(1, ndim)) for s in range(T)]

    def plane_slices(src_lo, m, offset):
        """Non-stream-axes slice of a resident plane padded by ``src_lo``,
        evaluated at margin ``m`` with access ``offset``."""
        sl = []
        for ax in range(1, ndim):
            start = int(src_lo[ax] - m[ax, 0] + offset[ax])
            size = grid_shape[ax] + int(m[ax, 0]) + int(m[ax, 1])
            sl.append(slice(start, start + size))
        return tuple(sl)

    def kernel(*refs):
        i = 0
        s_ref = refs[i]; i += 1                      # scalars (SMEM, f32)
        org_ref = refs[i]; i += 1                    # shard origin (SMEM, i32)
        in_refs = {f: refs[i + k] for k, f in enumerate(gh.group_inputs)}
        i += len(gh.group_inputs)
        coeff_refs = {c: refs[i + k] for k, c in enumerate(gh.group_coeffs)}
        i += len(gh.group_coeffs)
        out_refs = {f: refs[i + k] for k, f in enumerate(store_names)}
        i += len(store_names)
        buf_refs = {f: refs[i + k] for k, f in enumerate(gh.group_inputs)}
        i += len(gh.group_inputs)
        # per-stage rings of the *updated* persistent fields (stages 1..T-1)
        field_refs = [None]
        for _ in range(1, T):
            field_refs.append({f: refs[i + k]
                               for k, f in enumerate(gh.group_inputs)})
            i += len(gh.group_inputs)
        # per-stage temp rings (each chain stage recomputes its own temps)
        stage_ring_refs = []
        for _ in range(T):
            stage_ring_refs.append({t: refs[i + k]
                                    for k, t in enumerate(ring_names)})
            i += len(ring_names)
        # output staging ring: realigns completed planes to P-plane blocks
        # when the warm-up span is not a P-multiple
        stage_out_refs = {}
        if stage_r > 0:
            stage_out_refs = {f: refs[i + k]
                              for k, f in enumerate(store_names)}
            i += len(store_names)

        j_step = pl.program_id(0)

        @pl.when(j_step == 0)
        def _init():                    # fresh sweep: clear the carry
            carried = list(buf_refs.values())
            for s in range(1, T):
                carried += list(field_refs[s].values())
            for s in range(T):
                carried += list(stage_ring_refs[s].values())
            carried += list(stage_out_refs.values())
            for r in carried:
                r[...] = jnp.zeros_like(r)

        # append the P newly DMA'd planes behind every window buffer (the
        # single HBM fetch per plane per sweep); virtual step k's window is
        # cats[f][k+1 : k+1+depth], and the buffers commit a P-plane shift
        # once at the end of the grid step
        cats = {}
        for f in gh.group_inputs:
            cats[f] = jnp.concatenate([buf_refs[f][...], in_refs[f][...]],
                                      axis=0)
        field_vals = [None] + [{f: field_refs[s][f][...]
                                for f in gh.group_inputs}
                               for s in range(1, T)]
        ring_vals_all = [{t: stage_ring_refs[s][t][...] for t in ring_names}
                         for s in range(T)]
        coeff_windows = {c: r[...] for c, r in coeff_refs.items()}

        def scalar(name: str):
            return s_ref[scalar_index[name]]

        sdict = {nm: s_ref[scalar_index[nm]] for nm in p.scalars}
        completed = {f: [] for f in store_names}

        for k_plane in range(P):
            # virtual step: replays the single-plane sweep semantics with
            # t = j*P + k, so masking/ring/coefficient indexing is
            # bit-identical to the P=1 kernel
            t_step = j_step * P + k_plane
            for s in range(T):
                acc = T - 1 - s
                margins_s = stage_margins[s]
                # the interior plane stage s completes this virtual step
                # (negative during warm-up; the out index map clamps, and
                # every ring store masks by stream validity)
                c_plane = t_step - halo_lo[0] - (s + 1) * lead
                ring_vals = ring_vals_all[s]
                results: dict = {}
                memo: dict = {}

                for op in ops:
                    m = margins_s[op.out]
                    ext = tuple(grid_shape[ax] + int(m[ax, 0])
                                + int(m[ax, 1]) for ax in range(1, ndim))

                    def coeff(cr, m=m, s=s, t_step=t_step):
                        ax = coeff_axis[cr.coeff]
                        cvec = coeff_windows[cr.coeff]
                        if ax == 0:
                            # per-plane scalar, read at the (clamped) global
                            # plane stage s is completing
                            idx = jnp.clip(
                                t_step - (s + 1) * lead + cr.offset,
                                0, cvec.shape[0] - 1)
                            v = jax.lax.dynamic_slice(cvec, (idx,), (1,))
                            return v.reshape((1,) * (ndim - 1))
                        start = int(halo_lo[ax] - m[ax, 0] + cr.offset)
                        size = grid_shape[ax] + int(m[ax, 0]) + int(m[ax, 1])
                        v = cvec[start:start + size]
                        shape = [1] * (ndim - 1)
                        shape[ax - 1] = size
                        return v.reshape(shape)

                    def access(a: Access, m=m, s=s, k_plane=k_plane,
                               margins_s=margins_s, ring_vals=ring_vals,
                               results=results):
                        o0 = int(a.offset[0])
                        if a.field in produced:
                            pm = margins_s[a.field]
                            if a.field in ring_depth:
                                # past (or current) plane out of the ring
                                plane = ring_vals[a.field][
                                    ring_depth[a.field] - 1 + o0]
                            else:
                                plane = results[a.field]  # this step's value
                            return plane[plane_slices(pm[:, 0], m, a.offset)]
                        # persistent field: stage 0 reads the shift register
                        # (raw HBM planes; virtual step k's window starts at
                        # cats[k+1]), later stages the previous stage's
                        # updated-field ring — same index, one window behind
                        # the stream front
                        idx = depths[a.field] - 1 - lead + o0
                        if s == 0:
                            plane = cats[a.field][k_plane + 1 + idx]
                            src_lo = halo_lo
                        else:
                            plane = field_vals[s][a.field][idx]
                            src_lo = tuple((T - s) * hl[ax]
                                           for ax in range(ndim))
                        return plane[plane_slices(src_lo, m, a.offset)]

                    mkey = tuple(int(v) for v in m.flatten())
                    op_memo = memo.setdefault(mkey, {})
                    res = evaluate(op.expr, access, scalar, op_memo,
                                   coeff=coeff)
                    res = jnp.broadcast_to(jnp.asarray(res, dtype=dtype),
                                           ext)
                    if m[1:].any() \
                            and p.fields[op.out].boundary != "periodic":
                        mask = None
                        for ax in range(1, ndim):
                            if not m[ax].any():
                                continue
                            g0 = org_ref[ax] - int(m[ax, 0])
                            coord = g0 + jax.lax.broadcasted_iota(
                                jnp.int32, ext, ax - 1)
                            ok = (coord >= 0) & (coord < global_extent[ax])
                            mask = ok if mask is None else (mask & ok)
                        if mask is not None:
                            res = jnp.where(mask, res,
                                            jnp.asarray(0, dtype=dtype))
                    results[op.out] = res
                    if op.out in ring_vals:
                        # ring planes must honour zero-halo semantics along
                        # the stream axis: out-of-domain planes store as
                        # zeros (periodic temps with back-references were
                        # legalised into splits).  Rings shift per *virtual*
                        # step in registers; the refs commit once per grid
                        # step below.
                        cg = org_ref[0] + c_plane
                        ok = (cg >= 0) & (cg < global_extent[0])
                        stored = jnp.where(ok, res, jnp.zeros_like(res))
                        ring_vals[op.out] = jnp.concatenate(
                            [ring_vals[op.out][1:], stored[None]], axis=0)
                    if update is None and op.out in out_refs:
                        center = tuple(
                            slice(int(m[ax, 0]),
                                  int(m[ax, 0]) + grid_shape[ax])
                            for ax in range(1, ndim))
                        completed[op.out].append(res[center])

                if update is None:
                    break               # classic sweep: T == 1, no chaining
                # advance time: apply the fused-loop update rule plane-wise
                # at this stage's working extent.  Mid-chain the updated
                # planes feed stage s+1's rings (the next stage reads time
                # level s+1 without touching HBM); at stage T-1 they are
                # the stored result — the fields after T steps.
                ext_s = tuple(grid_shape[a] + acc * (hl[a] + hh[a])
                              for a in range(1, ndim))
                cur = {}
                for f in gh.group_inputs:
                    idx = depths[f] - 1 - lead
                    plane = (cats[f][k_plane + 1 + idx] if s == 0
                             else field_vals[s][f][idx])
                    # "in by one halo step": the source planes carry exactly
                    # one more accumulated halo than this stage's extent
                    cur[f] = plane[tuple(
                        slice(hl[ax], hl[ax] + ext_s[ax - 1])
                        for ax in range(1, ndim))]
                outs = {}
                for f in out_names:
                    m = margins[f]      # base margin; stage adds acc steps
                    outs[f] = results[f][tuple(
                        slice(int(m[ax, 0]), int(m[ax, 0]) + ext_s[ax - 1])
                        for ax in range(1, ndim))]
                merged = dict(cur)
                merged.update(update(cur, outs, sdict))
                if s == T - 1:
                    for f in gh.group_inputs:
                        completed[f].append(jnp.broadcast_to(
                            jnp.asarray(merged[f], dtype=dtype), ext_s))
                    break
                # re-impose zero-boundary semantics on the updated planes:
                # the rings stand in for the outer loop's re-padded carry,
                # so out-of-domain cells (non-stream margins and warm-up/
                # out-of-sweep planes) must store as zeros
                cg = org_ref[0] + c_plane
                ok = (cg >= 0) & (cg < global_extent[0])
                mask = jnp.broadcast_to(ok, ext_s)
                for ax in range(1, ndim):
                    if acc * (hl[ax] + hh[ax]) == 0 and grid_shape[ax] == \
                            global_extent[ax]:
                        continue
                    g0 = org_ref[ax] - acc * hl[ax]
                    coord = g0 + jax.lax.broadcasted_iota(jnp.int32, ext_s,
                                                          ax - 1)
                    mask = mask & (coord >= 0) & (coord < global_extent[ax])
                for f in gh.group_inputs:
                    v = jnp.broadcast_to(jnp.asarray(merged[f], dtype=dtype),
                                         ext_s)
                    stored = jnp.where(mask, v,
                                       jnp.asarray(0, dtype=dtype))
                    field_vals[s + 1][f] = jnp.concatenate(
                        [field_vals[s + 1][f][1:], stored[None]], axis=0)

        # commit the carries once per grid step: window buffers shift by P
        # planes, per-stage field/temp rings take their end-of-step values
        for f in gh.group_inputs:
            buf_refs[f][...] = cats[f][P:]
        for s in range(1, T):
            for f in gh.group_inputs:
                field_refs[s][f][...] = field_vals[s][f]
        for s in range(T):
            for t in ring_names:
                stage_ring_refs[s][t][...] = ring_vals_all[s][t]
        # emit the P-plane output block, realigned through the staging ring
        # (block b is finally correct at grid step j = b + K; the clamped
        # warm-up writes of block 0 are overwritten)
        for f in store_names:
            planes = completed[f]
            if stage_r > 0:
                staged = stage_out_refs[f][...]
                block = jnp.concatenate(
                    [staged] + [q[None] for q in planes[:P - stage_r]],
                    axis=0)
                stage_out_refs[f][...] = jnp.concatenate(
                    [q[None] for q in planes[P - stage_r:]], axis=0)
            else:
                block = jnp.concatenate([q[None] for q in planes], axis=0)
            out_refs[f][...] = block

    zeros_tail = (0,) * (ndim - 1)
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),   # scalars
                pl.BlockSpec(memory_space=pltpu.SMEM)]   # origin
    for _ in gh.group_inputs:
        # block index s covers element planes [s*P, (s+1)*P)
        in_specs.append(pl.BlockSpec((P,) + plane_ext,
                                     lambda s: (s,) + zeros_tail))
    for c in gh.group_coeffs:
        ax = coeff_axis[c]
        length = n_steps if ax == 0 else plane_ext[ax - 1]
        in_specs.append(pl.BlockSpec((length,), lambda s: (0,)))

    out_block = (P,) + grid_shape[1:]
    out_specs = tuple(
        pl.BlockSpec(out_block,
                     lambda s: (jnp.minimum(jnp.maximum(s - K, 0),
                                            n_out - 1),) + zeros_tail)
        for _ in store_names)
    # oversized by the P-round-up; run() slices the true extent back out
    out_shape = tuple(
        jax.ShapeDtypeStruct((n_out * P,) + grid_shape[1:], dtype)
        for _ in store_names)

    scratch = [pltpu.VMEM((depths[f],) + plane_ext, dtype)
               for f in gh.group_inputs]
    for s in range(1, T):
        for f in gh.group_inputs:
            scratch.append(pltpu.VMEM((depths[f],) + ring_plane_ext[s],
                                      dtype))
    for s in range(T):
        for t in ring_names:
            pm = stage_margins[s][t]
            ext_t = tuple(grid_shape[a] + int(pm[a, 0]) + int(pm[a, 1])
                          for a in range(1, ndim))
            scratch.append(pltpu.VMEM((ring_depth[t],) + ext_t, dtype))
    if stage_r > 0:
        for _ in store_names:
            scratch.append(pltpu.VMEM((stage_r,) + grid_shape[1:], dtype))

    call = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs if len(store_names) > 1 else out_specs[0],
        out_shape=out_shape if len(store_names) > 1 else out_shape[0],
        scratch_shapes=scratch,
        interpret=interpret,
    )

    expect = tuple(halo_lo[a] + grid_shape[a] + halo_hi[a]
                   for a in range(ndim))

    def run(padded_inputs: dict, scalars_vec=None,
            padded_coeffs: dict | None = None, origin=None,
            input_pad: dict | None = None):
        """Same contract as the block kernels: ``input_pad[f]`` gives the
        (ndim, 2) padding the provided array actually carries when it
        exceeds this region's window geometry (fused-loop carries); the
        expected window is sliced out statically."""
        svec = (scalars_vec if scalars_vec is not None
                else jnp.zeros((max(n_scalars, 1),), jnp.float32))
        org = (origin if origin is not None
               else jnp.zeros((ndim,), jnp.int32))
        args = [svec, org]
        for f in gh.group_inputs:
            x = padded_inputs[f]
            if input_pad is not None and f in input_pad:
                ip = input_pad[f]
                sl = tuple(slice(int(ip[a][0]) - halo_lo[a],
                                 int(ip[a][0]) - halo_lo[a] + expect[a])
                           for a in range(ndim))
                x = x[sl]
            if pad_round:
                # round the stream extent up to the P-plane DMA grid; the
                # zero planes only feed virtual steps whose completed
                # planes land past the domain and are sliced off below, so
                # the public pad_lo/pad_hi geometry is untouched
                x = jnp.pad(x, [(0, pad_round)] + [(0, 0)] * (ndim - 1))
            args.append(x)
        for c in gh.group_coeffs:
            args.append(padded_coeffs[c])
        res = call(*args)
        if len(store_names) == 1:
            res = (res,)
        if n_out * P != n0:
            res = tuple(x[:n0] for x in res)
        return dict(zip(store_names, res))

    # geometry for the shared orchestrators (identical to build_group_call)
    run.group_inputs = gh.group_inputs
    run.group_outputs = store_names
    run.returns_fields = update is not None
    run.group_coeffs = gh.group_coeffs
    run.coeff_axis = coeff_axis
    run.block = (1,) + grid_shape[1:]
    run.halo_lo = halo_lo
    run.halo_hi = halo_hi
    run.align_hi = (0,) * ndim
    run.pad_lo = halo_lo
    run.pad_hi = halo_hi
    run.window = (span + 1,) + plane_ext
    run.tiles = (n_tiles,)
    run.stream_axis = 0
    run.depths = depths
    run.rings = dict(ring_depth)
    run.chain = T           # chained stages: T-1 in-kernel updates per sweep
    run.plane_tile = P      # virtual steps (planes advanced) per grid step
    run.vmem_window_bytes = sum(
        (depths[f] + P) * int(np.prod(plane_ext)) for f in gh.group_inputs
    ) * np.dtype(np.float32 if dtype == jnp.float32 else np.float16).itemsize
    return run


def _build_calls(p: Program, plan: DataflowPlan, grid_shape,
                 graph: StreamGraph | None):
    dtype = _DTYPES[plan.dtype]
    if graph is None:
        graph = lower_to_dataflow(p, plan, grid_shape)
    calls = [build_stream_call(p, region, grid_shape, dtype=dtype,
                               interpret=plan.interpret,
                               plane_tile=getattr(graph, "plane_tile", 1))
             for region in graph.regions]
    return dtype, calls


def lower(p: Program, plan: DataflowPlan, grid_shape,
          graph: StreamGraph | None = None):
    """Return fn(fields, scalars, coeffs) -> outputs, one streamed sweep.

    Single-step execution never chains (there is no update rule to apply
    between stages), so any ``time_tile`` on the plan is ignored here;
    the graph's effective ``plane_tile`` applies — spatial unrolling needs
    no update rule."""
    if graph is None:
        graph = lower_to_dataflow(p, plan, grid_shape)
    dtype, calls = _build_calls(p, plan, grid_shape, graph)
    tracer = current_tracer()
    if tracer.enabled:
        tracer.event("StreamLowered", program=p.name, mode="single",
                     regions=len(calls), time_tile=1,
                     plane_tile=int(graph.plane_tile))
    return lower_from_calls(p, dtype, calls)


def lower_time_loop(p: Program, plan: DataflowPlan, grid_shape,
                    spec: TimeLoopSpec, update,
                    graph: StreamGraph | None = None):
    """Fused ``lax.fori_loop`` time loop over streamed sweeps: the carry
    holds pre-padded persistent fields (no alignment slab — streams never
    tile), each step runs every region's shift-register sweep, and the
    update rule is traced once.

    With an effective ``time_tile = T > 1`` on the graph, each loop
    iteration runs ONE chained sweep that advances T full steps (all T
    updates applied in-kernel between chain stages; the call returns the
    new fields and the loop body just writes them back into the carry), so
    the loop runs ``spec.steps // T`` iterations; a ``spec.steps % T``
    remainder runs once after the loop through a second, shallower chain
    built from the same region."""
    dtype = _DTYPES[plan.dtype]
    if graph is None:
        graph = lower_to_dataflow(p, plan, grid_shape)
    T = int(getattr(graph, "time_tile", 1))
    P = int(getattr(graph, "plane_tile", 1))
    tracer = current_tracer()
    if tracer.enabled:
        tracer.event("StreamLowered", program=p.name, mode="loop",
                     regions=len(graph.regions), time_tile=T, plane_tile=P)
    if T <= 1:
        _, calls = _build_calls(p, plan, grid_shape, graph)
        return time_loop_from_calls(p, dtype, grid_shape, spec, update,
                                    calls)
    region = graph.regions[0]       # chain legality implies a single region
    upd = adapt_update(update)
    calls = [build_stream_call(p, region, grid_shape, dtype=dtype,
                               interpret=plan.interpret, time_tile=T,
                               update=upd, plane_tile=P)]
    rem = int(spec.steps) % T
    epilogue = None
    if rem:
        epilogue = [build_stream_call(
            p, region, grid_shape, dtype=dtype, interpret=plan.interpret,
            time_tile=rem, update=upd, plane_tile=P)]
    return time_loop_from_calls(p, dtype, grid_shape, spec, update, calls,
                                chain=T, epilogue=epilogue)
