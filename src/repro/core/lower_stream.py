"""Stream backend: StreamGraph regions -> shift-register Pallas kernels.

This is the hardware materialisation of the dataflow layer
(:mod:`repro.core.dataflow`), the role the paper's HLS dialect plays for the
FPGA backends.  Per region, one ``pl.pallas_call`` whose **grid iterates
over the outer (stream) axis**, one step per plane:

* each external input field is DMA'd as exactly **one new plane per step**
  (BlockSpec of depth 1) — each input element is fetched from HBM once per
  sweep;
* the shift-register window buffers live in VMEM **scratch that persists
  across grid steps** (the kernel's carry): every step rolls each buffer
  one plane and appends the new plane, so the full stencil window along the
  stream axis is always resident without refetching (paper Fig. 2);
* in-region temps consumed at *past* planes keep a small ring buffer of
  their own recent planes — stream-axis dependencies cost storage, never
  recompute;
* the output plane trails the stream front by the region's lead: the output
  BlockSpec's index map clamps ``step - (lo+hi)`` so warm-up steps write
  (and later overwrite) plane 0, and every plane's final value is computed
  from a full window.

Boundary handling mirrors the block schedule: the orchestrator pre-pads the
stream axis (zero slabs or torus wraparound planes), non-stream margins are
masked against the global domain for zero-boundary fields, and ring-buffered
temps store zeros for out-of-domain planes.

The produced callables expose the same geometry attributes as
``kernels.stencil3d.build_group_call`` (``group_inputs``/``pad_lo``/
``input_pad`` slicing/…), so the generic orchestrators in
:mod:`repro.core.lower_pallas` — including the fused ``lax.fori_loop`` time
loop with carry-resident persistent fields — drive stream and block kernels
identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dataflow import StreamGraph, StreamRegion, lower_to_dataflow
from .expr_eval import evaluate
from .ir import Access, Program
from .lower_pallas import _DTYPES, lower_from_calls, time_loop_from_calls
from .schedule import DataflowPlan, TimeLoopSpec


def build_stream_call(p: Program, region: StreamRegion, grid_shape,
                      dtype=jnp.float32, interpret: bool = True,
                      global_extent=None):
    """Build a callable(padded_inputs, scalars, coeffs, origin) -> outputs
    streaming one region over the outer axis (see module docstring).

    ``padded_inputs`` must be padded by ``pad_lo``/``pad_hi`` (exposed on
    the returned callable); oversized persistent buffers ride in via the
    ``input_pad`` path exactly as for block kernels.
    """
    ndim = p.ndim
    gh = region.halo
    grid_shape = tuple(int(g) for g in grid_shape)
    if global_extent is None:
        global_extent = grid_shape
    global_extent = tuple(int(g) for g in global_extent)
    n0 = grid_shape[0]
    halo_lo = tuple(int(gh.input_halo[a, 0]) for a in range(ndim))
    halo_hi = tuple(int(gh.input_halo[a, 1]) for a in range(ndim))
    lead = halo_hi[0]
    span = halo_lo[0] + lead          # window depth along the stream - 1
    n_steps = n0 + span               # padded planes = one grid step each
    # padded plane extents on the non-stream axes (group-uniform halo)
    plane_ext = tuple(grid_shape[a] + halo_lo[a] + halo_hi[a]
                      for a in range(1, ndim))

    ops = [p.ops[i] for i in region.ops]
    margins = {p.ops[i].out: gh.margins[i] for i in region.ops}
    produced = {op.out for op in ops}
    out_names = [op.out for op in ops if op.out in set(gh.group_outputs)]
    coeff_axis = {c: p.coeffs[c] for c in gh.group_coeffs}
    depths = {f: int(region.depths[f]) for f in gh.group_inputs}
    ring_depth = {t: int(r) for t, r in region.rings.items()}
    ring_names = [op.out for op in ops if op.out in ring_depth]
    n_scalars = len(p.scalars)
    scalar_index = {s: i for i, s in enumerate(p.scalars)}
    # non-stream margin recompute needs the zero-halo mask unless the field
    # is periodic (wrapped planes are exact); the stream axis itself is
    # handled by input padding + ring-store masking, never here
    masked = {op.out: (margins[op.out][1:].any()
                       and p.fields[op.out].boundary != "periodic")
              for op in ops}

    def plane_slices(src_lo, m, offset):
        """Non-stream-axes slice of a resident plane padded by ``src_lo``,
        evaluated at margin ``m`` with access ``offset``."""
        sl = []
        for ax in range(1, ndim):
            start = int(src_lo[ax] - m[ax, 0] + offset[ax])
            size = grid_shape[ax] + int(m[ax, 0]) + int(m[ax, 1])
            sl.append(slice(start, start + size))
        return tuple(sl)

    def kernel(*refs):
        i = 0
        s_ref = refs[i]; i += 1                      # scalars (SMEM, f32)
        org_ref = refs[i]; i += 1                    # shard origin (SMEM, i32)
        in_refs = {f: refs[i + k] for k, f in enumerate(gh.group_inputs)}
        i += len(gh.group_inputs)
        coeff_refs = {c: refs[i + k] for k, c in enumerate(gh.group_coeffs)}
        i += len(gh.group_coeffs)
        out_refs = {f: refs[i + k] for k, f in enumerate(out_names)}
        i += len(out_names)
        buf_refs = {f: refs[i + k] for k, f in enumerate(gh.group_inputs)}
        i += len(gh.group_inputs)
        ring_refs = {t: refs[i + k] for k, t in enumerate(ring_names)}

        s = pl.program_id(0)

        @pl.when(s == 0)
        def _init():                    # fresh sweep: clear the carry
            for r in list(buf_refs.values()) + list(ring_refs.values()):
                r[...] = jnp.zeros_like(r)

        # shift every window buffer one plane and append the new plane
        # (the single per-step HBM fetch)
        windows = {}
        for f in gh.group_inputs:
            v = jnp.concatenate([buf_refs[f][...][1:], in_refs[f][...]],
                                axis=0)
            buf_refs[f][...] = v
            windows[f] = v
        ring_vals = {t: ring_refs[t][...] for t in ring_names}
        coeff_windows = {c: r[...] for c, r in coeff_refs.items()}

        # the output plane this step completes (negative during warm-up;
        # the out index map clamps, and ring stores mask by validity)
        c_plane = s - span
        results: dict = {}
        memo: dict = {}

        def scalar(name: str):
            return s_ref[scalar_index[name]]

        for op in ops:
            m = margins[op.out]
            ext = tuple(grid_shape[ax] + int(m[ax, 0]) + int(m[ax, 1])
                        for ax in range(1, ndim))

            def coeff(cr, m=m):
                ax = coeff_axis[cr.coeff]
                cvec = coeff_windows[cr.coeff]
                if ax == 0:
                    # per-plane scalar, read at the (clamped) global plane
                    idx = jnp.clip(s - lead + cr.offset, 0,
                                   cvec.shape[0] - 1)
                    v = jax.lax.dynamic_slice(cvec, (idx,), (1,))
                    return v.reshape((1,) * (ndim - 1))
                start = int(halo_lo[ax] - m[ax, 0] + cr.offset)
                size = grid_shape[ax] + int(m[ax, 0]) + int(m[ax, 1])
                v = cvec[start:start + size]
                shape = [1] * (ndim - 1)
                shape[ax - 1] = size
                return v.reshape(shape)

            def access(a: Access, m=m):
                o0 = int(a.offset[0])
                if a.field in produced:
                    pm = margins[a.field]
                    if a.field in ring_refs:
                        # past (or current) plane out of the temp's ring
                        plane = ring_vals[a.field][
                            ring_depth[a.field] - 1 + o0]
                    else:
                        plane = results[a.field]        # this step's value
                    return plane[plane_slices(pm[:, 0], m, a.offset)]
                # external input: resident plane of the shift register
                plane = windows[a.field][depths[a.field] - 1 - lead + o0]
                return plane[plane_slices(halo_lo, m, a.offset)]

            mkey = tuple(int(v) for v in m.flatten())
            op_memo = memo.setdefault(mkey, {})
            res = evaluate(op.expr, access, scalar, op_memo, coeff=coeff)
            res = jnp.broadcast_to(jnp.asarray(res, dtype=dtype), ext)
            if masked[op.out]:
                mask = None
                for ax in range(1, ndim):
                    if not m[ax].any():
                        continue
                    g0 = org_ref[ax] - int(m[ax, 0])
                    coord = g0 + jax.lax.broadcasted_iota(jnp.int32, ext,
                                                          ax - 1)
                    ok = (coord >= 0) & (coord < global_extent[ax])
                    mask = ok if mask is None else (mask & ok)
                if mask is not None:
                    res = jnp.where(mask, res, jnp.asarray(0, dtype=dtype))
            results[op.out] = res
            if op.out in ring_refs:
                # ring planes must honour zero-halo semantics along the
                # stream axis: out-of-domain planes store as zeros (periodic
                # temps with back-references were legalised into splits)
                cg = org_ref[0] + c_plane
                ok = (cg >= 0) & (cg < global_extent[0])
                stored = jnp.where(ok, res, jnp.zeros_like(res))
                v = jnp.concatenate([ring_vals[op.out][1:], stored[None]],
                                    axis=0)
                ring_refs[op.out][...] = v
                ring_vals[op.out] = v
            if op.out in out_refs:
                center = tuple(slice(int(m[ax, 0]),
                                     int(m[ax, 0]) + grid_shape[ax])
                               for ax in range(1, ndim))
                out_refs[op.out][...] = res[center][None]

    zeros_tail = (0,) * (ndim - 1)
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),   # scalars
                pl.BlockSpec(memory_space=pltpu.SMEM)]   # origin
    for _ in gh.group_inputs:
        in_specs.append(pl.BlockSpec((1,) + plane_ext,
                                     lambda s: (s,) + zeros_tail))
    for c in gh.group_coeffs:
        ax = coeff_axis[c]
        length = n_steps if ax == 0 else plane_ext[ax - 1]
        in_specs.append(pl.BlockSpec((length,), lambda s: (0,)))

    out_block = (1,) + grid_shape[1:]
    out_specs = tuple(
        pl.BlockSpec(out_block,
                     lambda s: (jnp.maximum(s - span, 0),) + zeros_tail)
        for _ in out_names)
    out_shape = tuple(jax.ShapeDtypeStruct(grid_shape, dtype)
                      for _ in out_names)

    scratch = [pltpu.VMEM((depths[f],) + plane_ext, dtype)
               for f in gh.group_inputs]
    for t in ring_names:
        pm = margins[t]
        ext_t = tuple(grid_shape[a] + int(pm[a, 0]) + int(pm[a, 1])
                      for a in range(1, ndim))
        scratch.append(pltpu.VMEM((ring_depth[t],) + ext_t, dtype))

    call = pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=in_specs,
        out_specs=out_specs if len(out_names) > 1 else out_specs[0],
        out_shape=out_shape if len(out_names) > 1 else out_shape[0],
        scratch_shapes=scratch,
        interpret=interpret,
    )

    expect = tuple(halo_lo[a] + grid_shape[a] + halo_hi[a]
                   for a in range(ndim))

    def run(padded_inputs: dict, scalars_vec=None,
            padded_coeffs: dict | None = None, origin=None,
            input_pad: dict | None = None):
        """Same contract as the block kernels: ``input_pad[f]`` gives the
        (ndim, 2) padding the provided array actually carries when it
        exceeds this region's window geometry (fused-loop carries); the
        expected window is sliced out statically."""
        svec = (scalars_vec if scalars_vec is not None
                else jnp.zeros((max(n_scalars, 1),), jnp.float32))
        org = (origin if origin is not None
               else jnp.zeros((ndim,), jnp.int32))
        args = [svec, org]
        for f in gh.group_inputs:
            x = padded_inputs[f]
            if input_pad is not None and f in input_pad:
                ip = input_pad[f]
                sl = tuple(slice(int(ip[a][0]) - halo_lo[a],
                                 int(ip[a][0]) - halo_lo[a] + expect[a])
                           for a in range(ndim))
                x = x[sl]
            args.append(x)
        for c in gh.group_coeffs:
            args.append(padded_coeffs[c])
        res = call(*args)
        if len(out_names) == 1:
            res = (res,)
        return dict(zip(out_names, res))

    # geometry for the shared orchestrators (identical to build_group_call)
    run.group_inputs = gh.group_inputs
    run.group_outputs = out_names
    run.group_coeffs = gh.group_coeffs
    run.coeff_axis = coeff_axis
    run.block = (1,) + grid_shape[1:]
    run.halo_lo = halo_lo
    run.halo_hi = halo_hi
    run.align_hi = (0,) * ndim
    run.pad_lo = halo_lo
    run.pad_hi = halo_hi
    run.window = (span + 1,) + plane_ext
    run.tiles = (n_steps,)
    run.stream_axis = 0
    run.depths = depths
    run.rings = dict(ring_depth)
    run.vmem_window_bytes = sum(
        depths[f] * int(np.prod(plane_ext)) for f in gh.group_inputs
    ) * np.dtype(np.float32 if dtype == jnp.float32 else np.float16).itemsize
    return run


def _build_calls(p: Program, plan: DataflowPlan, grid_shape,
                 graph: StreamGraph | None):
    dtype = _DTYPES[plan.dtype]
    if graph is None:
        graph = lower_to_dataflow(p, plan, grid_shape)
    calls = [build_stream_call(p, region, grid_shape, dtype=dtype,
                               interpret=plan.interpret)
             for region in graph.regions]
    return dtype, calls


def lower(p: Program, plan: DataflowPlan, grid_shape,
          graph: StreamGraph | None = None):
    """Return fn(fields, scalars, coeffs) -> outputs, one streamed sweep."""
    dtype, calls = _build_calls(p, plan, grid_shape, graph)
    return lower_from_calls(p, dtype, calls)


def lower_time_loop(p: Program, plan: DataflowPlan, grid_shape,
                    spec: TimeLoopSpec, update,
                    graph: StreamGraph | None = None):
    """Fused ``lax.fori_loop`` time loop over streamed sweeps: the carry
    holds pre-padded persistent fields (no alignment slab — streams never
    tile), each step runs every region's shift-register sweep, and the
    update rule is traced once."""
    dtype, calls = _build_calls(p, plan, grid_shape, graph)
    return time_loop_from_calls(p, dtype, grid_shape, spec, update, calls)
