"""Transformation passes: stencil IR -> dataflow structure (paper §3.3).

The paper's nine FPGA transformations map here as:

  1. classify_args           -> :func:`classify`
  2. 512-bit packed interface-> lane alignment handled by the planner
                                (schedule.auto_plan picks 128-multiple blocks)
  3. streams                 -> fuse-group boundaries = materialised HBM
                                "streams"; inside a group the Pallas grid
                                pipeline is the stream
  4. per-field dataflow split-> :func:`stage_split` (one op per output field
                                is the IR normal form; grouping decides what
                                shares a window fetch)
  5. shift-buffer access map -> :func:`infer_halo` margins drive the window
                                slicing in the backends
  6. streamed write_data     -> Blocked output specs in the Pallas backend
  7. single load_data        -> shared input windows inside a fuse group
  8. small data -> BRAM      -> scalars lowered to SMEM/grid constants
  9. bundle per field        -> per-field PartitionSpec in core.distribute

:func:`infer_halo` also implements *overlapped tiling with recompute* for
in-group producer->consumer dependencies (tracer advection's structure): a
producer consumed at offset ``o`` by an op with margin ``(lo, hi)`` must be
evaluated on the extended region ``(lo - o, hi + o)``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .ir import Expr, FieldRole, Program


# --------------------------------------------------------------------------
# 1. argument classification
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ArgClass:
    inputs: list       # external field inputs (read, never written)
    outputs: list      # stored results
    temps: list        # internal producer/consumer fields
    scalars: list      # runtime scalars ("small data")


def classify(p: Program) -> ArgClass:
    return ArgClass(inputs=p.input_fields(), outputs=p.output_fields(),
                    temps=p.temp_fields(), scalars=list(p.scalars))


# --------------------------------------------------------------------------
# Margins & halos (asymmetric, per axis)
# --------------------------------------------------------------------------

def _zeros(ndim: int) -> np.ndarray:
    return np.zeros((ndim, 2), dtype=np.int64)  # [:,0]=lo, [:,1]=hi


@dataclasses.dataclass
class GroupHalo:
    """Result of halo inference for one fuse group."""
    margins: dict          # op index -> (ndim,2) evaluation margin
    input_halo: np.ndarray  # (ndim,2) uniform window halo for group inputs
    group_inputs: list     # field names read from outside the group
    group_outputs: list    # field names leaving the group (stored or read later)
    internal: list         # fields produced & consumed strictly inside
    group_coeffs: list = dataclasses.field(default_factory=list)


def infer_halo(p: Program, group: Sequence[int]) -> GroupHalo:
    """Compute evaluation margins and window halo for a fuse group.

    ``group`` is a list of op indices (program order).  An op consumed by a
    later op *inside* the group is recomputed on an extended margin
    (overlapped tiling); fields consumed from *outside* the group are window
    inputs with halo.
    """
    group = list(group)
    gset = set(group)
    ndim = p.ndim
    producer = {p.ops[i].out: i for i in group}

    # which group fields escape (stored, or consumed by a later group)?
    consumed_later = set()
    for j, op in enumerate(p.ops):
        if j in gset:
            continue
        for a in op.accesses():
            consumed_later.add(a.field)
    group_outputs = []
    internal = []
    for i in group:
        out = p.ops[i].out
        role = p.fields[out].role
        if role == FieldRole.OUTPUT or out in consumed_later:
            group_outputs.append(out)
        else:
            internal.append(out)

    # margins: reverse order; consumers propagate need to producers
    margins = {i: _zeros(ndim) for i in group}
    for i in reversed(group):
        op = p.ops[i]
        m = margins[i]
        for a in op.accesses():
            if a.field in producer and producer[a.field] in gset:
                pi = producer[a.field]
                if pi >= i:
                    raise ValueError("dependency violates program order")
                need = _zeros(ndim)
                for ax in range(ndim):
                    o = a.offset[ax]
                    need[ax, 0] = max(0, m[ax, 0] - o)
                    need[ax, 1] = max(0, m[ax, 1] + o)
                margins[pi] = np.maximum(margins[pi], need)

    # window halo for external inputs = max over (margin + offset)
    halo = _zeros(ndim)
    group_inputs = []
    group_coeffs = []
    for i in group:
        op = p.ops[i]
        m = margins[i]
        for a in op.accesses():
            if a.field in producer:
                continue
            if a.field not in group_inputs:
                group_inputs.append(a.field)
            for ax in range(ndim):
                o = a.offset[ax]
                halo[ax, 0] = max(halo[ax, 0], m[ax, 0] - o)
                halo[ax, 1] = max(halo[ax, 1], m[ax, 1] + o)
        for c in op.coeff_refs():
            ax = p.coeffs[c.coeff]
            if c.coeff not in group_coeffs:
                group_coeffs.append(c.coeff)
            halo[ax, 0] = max(halo[ax, 0], m[ax, 0] - c.offset)
            halo[ax, 1] = max(halo[ax, 1], m[ax, 1] + c.offset)
    return GroupHalo(margins=margins, input_halo=halo,
                     group_inputs=group_inputs, group_outputs=group_outputs,
                     internal=internal, group_coeffs=group_coeffs)


def field_halo(p: Program) -> np.ndarray:
    """Whole-program max |offset| halo (used by the distributed executor)."""
    halo = _zeros(p.ndim)
    for op in p.ops:
        for a in op.accesses():
            for ax in range(p.ndim):
                o = a.offset[ax]
                halo[ax, 0] = max(halo[ax, 0], -o)
                halo[ax, 1] = max(halo[ax, 1], o)
    return halo


# --------------------------------------------------------------------------
# 4. stage splitting / fusion grouping
# --------------------------------------------------------------------------

#: Max recompute margin the ``auto`` strategy tolerates before cutting a fuse
#: group (≈ a halo-1 producer->consumer chain of depth 6).  Beyond this the
#: overlapped-tiling recompute volume grows faster than the HBM traffic a
#: larger group saves.
RECOMPUTE_MARGIN_CAP = 6

STAGE_SPLIT_STRATEGIES = ("fused", "per_field", "auto")

def live_ops(p: Program) -> list:
    """Dead-code elimination: op indices transitively feeding a stored output."""
    producer = {op.out: i for i, op in enumerate(p.ops)}
    live: set = set()
    work = [producer[f] for f in p.output_fields()]
    while work:
        i = work.pop()
        if i in live:
            continue
        live.add(i)
        for a in p.ops[i].accesses():
            j = producer.get(a.field)
            if j is not None and j not in live:
                work.append(j)
    return sorted(live)


def stage_split(p: Program, strategy: str = "auto") -> list:
    """Partition ops into ordered fuse groups.

    ``fused``     – one group containing every op (single kernel; shared
                    window fetch = the paper's single load_data stage, with
                    in-group recompute for dependencies).
    ``per_field`` – one group per op (the paper's literal per-field dataflow
                    split; intermediates stream through HBM).
    ``auto``      – fused, split only when recompute margins explode
                    (dependency chains deeper than ~3 halo widths).
    """
    alive = live_ops(p)
    if strategy == "per_field":
        return [[i] for i in alive]
    if strategy == "fused":
        return [alive]
    if strategy != "auto":
        raise ValueError(
            f"unknown stage_split strategy {strategy!r}; valid strategies: "
            + ", ".join(repr(s) for s in STAGE_SPLIT_STRATEGIES))
    # auto: greedily grow a group; cut when max margin exceeds threshold
    groups: list = []
    cur: list = []
    for i in alive:
        trial = cur + [i]
        gh = infer_halo(p, trial)
        worst = max((int(m.max()) for m in gh.margins.values()), default=0)
        if cur and worst > RECOMPUTE_MARGIN_CAP:
            groups.append(cur)
            cur = [i]
        else:
            cur = trial
    if cur:
        groups.append(cur)
    return groups


# --------------------------------------------------------------------------
# CSE statistics (Expr is hash-consed at lowering; this measures sharing)
# --------------------------------------------------------------------------

def cse_stats(p: Program) -> dict:
    seen: dict = {}

    def rec(e: Expr):
        seen[e] = seen.get(e, 0) + 1
        for c in e.children():
            rec(c)

    for op in p.ops:
        rec(op.expr)
    shared = sum(v - 1 for v in seen.values() if v > 1)
    return {"unique_nodes": len(seen), "reused_evals_saved": shared}
