"""End-to-end driver: Program -> DataflowPlan -> compiled executable.

The user-facing API (the role PSyclone's code-generation entry point plays):

    prog = pw_advection()
    ex = compile_program(prog, (64, 64, 128), options=CompileOptions(
             backend="pallas"))
    out = ex(fields, scalars, coeffs)          # dict of output arrays

``CompileOptions`` is a frozen dataclass — build a new value per
configuration (``dataclasses.replace`` to vary one knob) rather than
mutating; loose kwargs (``compile_program(prog, grid, backend="pallas")``)
remain accepted and normalise to the same object.

Backends:
    "pallas"     generated Pallas dataflow kernels (the paper's contribution)
    "jnp_fused"  XLA-fused full-array execution  (DaCe-role baseline)
    "jnp_naive"  op-at-a-time full-array execution (unoptimised-HLS role)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Mapping

import jax

from ..obs.events import PlanChosen
from ..obs.metrics import global_metrics
from ..obs.trace import resolve_tracer
from . import dataflow, distribute, lower_jnp, lower_pallas, lower_stream
from .ir import Program
from .passes import infer_halo
from .schedule import (DataflowPlan, ShardSpec, TimeLoopSpec, auto_plan,
                       make_shard_spec, normalize_mesh_axes, plan_time_loop,
                       shard_local_grid)

_BACKENDS = ("pallas", "jnp_fused", "jnp_naive")


class TileDemotionWarning(UserWarning):
    """An explicitly requested ``time_tile``/``plane_tile`` was demoted by
    stream legalisation — the compile still succeeds, at the effective
    depth/width recorded on ``plan.stream`` (the structured reason is in
    the message and in the ``ChainDemoted``/``PlaneDemoted`` trace event)."""


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Every compile-time knob of :func:`compile_program`, as one frozen
    value object — the canonical way to configure a compile:

        ex = compile_program(p, grid, options=CompileOptions(
                 schedule="stream", steps=16, update=rule, time_tile=4))

    Loose keyword arguments remain accepted (``compile_program(p, grid,
    steps=16, ...)``) and are normalised into a ``CompileOptions``
    internally, so both spellings hit the same validation; passing a knob
    *both* ways with different values is an error, never a silent pick.
    Being frozen, an options value can be shared between compiles (the
    serving engine, the tuner, benchmarks) without copy-on-write concerns.

    ``time_tile`` is the temporal-blocking depth: pipeline that many time
    steps through one stream sweep (requires ``schedule="stream"`` and a
    fused loop, i.e. ``steps``/``update``).  ``None`` defers to the plan
    (heuristic and tuned plans carry their own depth); an integer forces
    the requested depth, which stream legalisation may still demote to 1
    (see ``StreamSpec.time_tile``).

    ``plane_tile`` is the spatial-unroll width: DMA + compute that many
    consecutive planes per stream sweep grid step (requires
    ``schedule="stream"``; unlike ``time_tile`` it needs no fused loop —
    single-step sweeps unroll too).  ``None`` defers to the plan; an
    integer forces the requested width, which geometry may still demote
    to 1 (see ``StreamSpec.plane_tile``).

    ``trace`` enables structured tracing for this compile: a
    :class:`repro.obs.Tracer` (or ``True`` to install a fresh process
    tracer).  ``None`` defers to the ambient tracer — the process-wide
    no-op unless one was installed via ``repro.obs.set_tracer`` or
    ``REPRO_TRACE=path`` — so tracing is off by default with branch-only
    overhead.
    """

    backend: str = "pallas"
    plan: DataflowPlan | None = None
    jit: bool = True
    interpret: bool = True
    dtype: str = "float32"
    strategy: str = "auto"
    steps: int | None = None
    update: object = None
    carry_write: str | None = None
    tune_config: object = None
    plan_cache: object = None
    mesh: object = None
    mesh_axes: tuple | None = None
    boundary: object = None
    schedule: str | None = None
    time_tile: int | None = None
    plane_tile: int | None = None
    trace: object = None


_OPTION_DEFAULTS = {f.name: f.default
                    for f in dataclasses.fields(CompileOptions)}


def _resolve_options(options, kwargs) -> CompileOptions:
    """Merge the ``options=`` object and loose kwargs into one validated
    :class:`CompileOptions` (the single normalisation point)."""
    unknown = set(kwargs) - set(_OPTION_DEFAULTS)
    if unknown:
        raise TypeError(
            "unknown compile option(s) "
            + ", ".join(sorted(repr(k) for k in unknown))
            + "; valid options: "
            + ", ".join(sorted(_OPTION_DEFAULTS)))
    if options is None:
        return CompileOptions(**kwargs)
    if not isinstance(options, CompileOptions):
        raise TypeError(
            f"options= must be a CompileOptions, got "
            f"{type(options).__name__}")
    if not kwargs:
        return options
    for k, v in kwargs.items():
        cur = getattr(options, k)
        if cur is v or cur == _OPTION_DEFAULTS[k]:
            continue            # kwarg refines a knob the options left alone
        try:
            same = bool(v == cur)
        except Exception:
            same = False
        if not same:
            raise ValueError(
                f"compile option {k!r} passed both in options= ({cur!r}) "
                f"and as a keyword ({v!r}); set it one way, not both")
    return dataclasses.replace(options, **kwargs)


def _check_schedule(backend: str, schedule: str | None) -> None:
    """THE capability gate for schedule x backend x mesh combinations.

    Every compile path funnels through here — the explicitly requested
    ``schedule=`` before planning, and the plan-carried schedule after
    retargeting — so an unsupported combination fails fast with one
    message, never deep inside a lowering.  Valid combinations:

    * ``schedule="block"``  — any backend; local or ``mesh=``; single-step
      or fused ``steps=``;
    * ``schedule="stream"`` — ``backend="pallas"`` only; local or
      ``mesh=`` (the stream axis may itself be sharded), single-step or
      fused ``steps=``, ``time_tile >= 1``.
    """
    if schedule == "stream" and backend != "pallas":
        raise ValueError(
            "schedule='stream' is a pallas dataflow schedule; backend "
            f"{backend!r} has no streaming lowering. Valid combinations: "
            "schedule='block' with any backend (local or mesh=), or "
            "schedule='stream' with backend='pallas' (local or mesh=, "
            "time_tile >= 1)")


@dataclasses.dataclass
class CompiledStencil:
    program: Program
    plan: DataflowPlan
    grid: tuple
    _fn: object
    jitted: bool
    # fused time loop (``steps=N``): the executable returns the *final
    # fields* after N on-device iterations instead of one step's outputs
    time_spec: TimeLoopSpec | None = None
    # SPMD compile (``mesh=...``): the distributed layout; None = local
    shard: ShardSpec | None = None

    def __call__(self, fields: Mapping, scalars: Mapping | None = None,
                 coeffs: Mapping | None = None) -> dict:
        return self._fn(dict(fields), dict(scalars or {}), dict(coeffs or {}))


def compile_program(p: Program, grid, *,
                    options: CompileOptions | None = None,
                    **kwargs) -> CompiledStencil:
    """Compile ``p`` for ``grid`` — local or SPMD, single-step or fused loop.

    Configuration rides in a :class:`CompileOptions` (``options=``), or as
    loose keyword arguments with the same names — both are normalised into
    one validated ``CompileOptions`` before any work happens, and passing
    the same knob both ways with different values raises.

    With ``steps=N`` and an ``update(fields, outputs) -> fields`` rule, the
    whole time loop is lowered into the compiled program (one ``jax.jit``
    dispatch per call): the loop carry keeps the input fields resident and
    pre-padded on device, and ``update`` is traced into the loop body.  The
    executable then maps initial fields to the fields after N steps —
    exactly N iterations of :func:`run_time_loop`, without N dispatches,
    N ``jnp.pad`` rounds, or N host round trips.

    With ``mesh=`` (a ``jax.sharding.Mesh``) and ``mesh_axes=`` (mesh axis
    name per grid axis, None entries unsharded), the same program compiles
    SPMD: fields are domain-decomposed ``P(*mesh_axes)``, halos travel by
    ``ppermute``, and the plan is priced against the per-shard *local*
    block.  Combined with ``steps=N`` the halo exchange moves inside the
    fused loop carry — N distributed steps in one dispatch (see
    :func:`repro.core.distribute.lower_sharded_time_loop`).

    ``boundary=`` overrides the program's per-field boundary declarations
    before compiling: a single kind (``"zero"`` / ``"periodic"`` for a
    torus) or a ``{field: kind}`` mapping (see ``Program.with_boundary``).

    ``schedule=`` selects the Pallas iteration schedule: ``"block"``
    (tiled output, overlapping VMEM windows per tile) or ``"stream"`` (the
    paper's shift-register dataflow: the kernel grid sweeps the outer axis
    plane-by-plane with rolling window buffers in the kernel carry, so each
    input element is fetched from HBM once per sweep — see
    :mod:`repro.core.dataflow` / :mod:`repro.core.lower_stream`).  ``None``
    keeps the plan's schedule (``"block"`` for heuristic plans; tuned plans
    carry whichever schedule measured fastest).  Streaming is pallas-only
    and composes with ``mesh=``: each shard sweeps the stream axis over
    its local block, halo refresh stays inside the fused-loop carry, and a
    sharded stream axis gets exact (chain-deepened) neighbour ghost planes
    (see :func:`_check_schedule` for the supported combinations).

    ``strategy="tuned"`` replaces the ``auto_plan`` heuristic with the
    measured search of :mod:`repro.core.tune`: the persistent plan cache is
    consulted first (a hit compiles the stored plan with zero timed runs);
    on a miss the tuner measures model-pruned candidates and persists the
    winner.  ``tune_config`` (:class:`~repro.core.tune.TuneConfig`) and
    ``plan_cache`` (:class:`~repro.core.tune.PlanCache`) override the search
    knobs and cache location.  ``carry_write=None`` defers to the tuned
    style (or ``"repad"`` under any other strategy).

    ``time_tile=T`` (temporal blocking, stream schedule only) pipelines T
    time steps through every sweep: the fused loop then runs ``steps // T``
    chained sweeps plus one remainder sweep, and each input plane is
    fetched from HBM once per T steps.  Requires ``steps``/``update``; the
    stream legaliser may demote the *effective* depth to 1 (recorded on
    ``plan.stream.time_tile``) when the program cannot chain.

    ``plane_tile=P`` (spatial unrolling, stream schedule only) advances P
    consecutive planes per sweep grid step: the sweep grid shrinks to
    ``ceil(n_steps / P)`` and window buffers shift by P planes at a time.
    Composes with ``time_tile`` (a P×T tile) and needs no fused loop; the
    legaliser demotes the *effective* width to 1 (recorded on
    ``plan.stream.plane_tile``) when P exceeds the shard-local extent.
    """
    o = _resolve_options(options, kwargs)
    tracer = resolve_tracer(o.trace)
    with tracer.active(), tracer.span(
            "compile", program=p.name,
            grid="x".join(str(int(g)) for g in grid),
            backend=o.backend, strategy=o.strategy) as sp:
        return _compile(p, grid, o, tracer, sp)


def _compile(p: Program, grid, o: CompileOptions, tracer,
             sp) -> CompiledStencil:
    """The compile body, running inside ``compile_program``'s span (with
    ``tracer`` installed as the ambient one, so the layers below — plan
    legalisation, tuning, sharded/stream lowering — emit into it without
    threading a tracer argument everywhere)."""
    backend, plan, jit, interpret = o.backend, o.plan, o.jit, o.interpret
    dtype, strategy, steps, update = o.dtype, o.strategy, o.steps, o.update
    carry_write, tune_config = o.carry_write, o.tune_config
    plan_cache, mesh, mesh_axes = o.plan_cache, o.mesh, o.mesh_axes
    boundary, schedule, time_tile = o.boundary, o.schedule, o.time_tile
    plane_tile = o.plane_tile
    metrics = global_metrics()
    metrics.counter("compile.compiles").inc()

    grid = tuple(int(g) for g in grid)
    if len(grid) != p.ndim:
        raise ValueError(f"grid rank {len(grid)} != program ndim {p.ndim}")
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    _check_schedule(backend, schedule)
    if time_tile is not None:
        time_tile = int(time_tile)
        if time_tile < 1:
            raise ValueError(f"time_tile must be >= 1, got {time_tile}")
        if time_tile > 1 and steps is None:
            raise ValueError(
                "time_tile > 1 pipelines T time steps through one stream "
                "sweep, which applies the update rule in-kernel — it needs "
                "the fused loop: pass steps=N and update=")
    if plane_tile is not None:
        plane_tile = int(plane_tile)
        if plane_tile < 1:
            raise ValueError(
                f"plane_tile must be >= 1, got {plane_tile}")
    if boundary is not None:
        p = p.with_boundary(boundary)

    ndim = p.ndim
    if mesh is not None:
        if mesh_axes is None:
            mesh_axes = tuple(mesh.axis_names)
        mesh_axes = normalize_mesh_axes(mesh_axes, ndim)
        # the planner prices VMEM blocks against the per-shard local grid
        plan_grid = shard_local_grid(grid, mesh, mesh_axes)
    elif mesh_axes is not None:
        raise ValueError("mesh_axes requires mesh=")
    else:
        plan_grid = grid

    tuned_cw = None
    tuned_rec = None
    if plan is None:
        if strategy == "tuned":
            from . import tune
            res = tune.get_tuned_plan(p, grid, backend=backend,
                                      interpret=interpret, dtype=dtype,
                                      update=update, config=tune_config,
                                      cache=plan_cache,
                                      mesh=mesh, mesh_axes=mesh_axes)
            plan, tuned_cw = res.plan, res.carry_write
            tuned_rec = res.record
        else:
            plan = auto_plan(p, plan_grid, backend=backend,
                             interpret=interpret, dtype=dtype,
                             strategy=strategy, steps=steps,
                             schedule=schedule or "block",
                             time_tile=time_tile or 1,
                             plane_tile=plane_tile or 1)
    # plans can be shared (PlanCache entries, caller-held objects): the
    # compiled executable always gets its own deep copy, retargeted to the
    # requested backend/mesh, so no compile ever mutates another's plan
    overrides = {}
    if plan.backend != backend:
        overrides["backend"] = backend
    if mesh is not None and plan.mesh_axes_for(ndim) != mesh_axes:
        overrides["mesh_axes"] = mesh_axes
    if time_tile is not None and plan.time_tile != time_tile:
        overrides["time_tile"] = time_tile
    if plane_tile is not None and plan.plane_tile != plane_tile:
        overrides["plane_tile"] = plane_tile
    if schedule is not None and plan.schedule != schedule:
        # retargeting the schedule invalidates any cached stream geometry;
        # a stream plan's block is a degenerate one-plane placeholder, so
        # converting to "block" re-derives a real tile from the heuristic
        # (and drops any temporal chain — it is stream-only)
        overrides.update(schedule=schedule, stream=None)
        if schedule == "block" and plan.schedule == "stream":
            overrides.setdefault("time_tile", 1)
            overrides.setdefault("plane_tile", 1)
            overrides["block"] = auto_plan(
                p, plan_grid, backend=backend, interpret=interpret,
                dtype=plan.dtype, steps=steps).block
    plan = dataclasses.replace(plan, groups=[list(g) for g in plan.groups],
                               **overrides)
    if carry_write is None:
        carry_write = tuned_cw or "repad"

    graph = None
    group_halos = None
    stream_axis = None
    if plan.schedule == "stream":
        _check_schedule(backend, plan.schedule)
        metrics.counter("compile.stream_lowerings").inc()
        update_demote = None
        if plan.time_tile > 1 and not getattr(update, "_plane_local", True):
            # chained stages run the update inside the kernel on resident
            # planes; an update that reads the whole grid (e.g. the serving
            # layer's bucket refresh) has no plane-local form, so the chain
            # demotes to 1 — the step-level analog of chain_split_reason
            update_demote = ("update rule is not plane-local (it reads "
                             "beyond the resident planes), so chained "
                             "stages cannot apply it in-kernel")
            plan = dataclasses.replace(plan, time_tile=1)
        stream_axis = dataflow.STREAM_AXIS
        # a mesh that decomposes the sweep axis needs exact, chain-deepened
        # ghost planes on the lo side — the dataflow graph carries that
        stream_sharded = (
            mesh is not None
            and mesh_axes[stream_axis] is not None
            and int(mesh.shape[mesh_axes[stream_axis]]) > 1)
        # legalise fusion + size the shift registers once; carry sizing,
        # the shard spec, the plan's cached StreamSpec and the kernels all
        # share it
        graph = dataflow.lower_to_dataflow(p, plan, plan_grid,
                                           stream_sharded=stream_sharded)
        plan = dataclasses.replace(plan, stream=graph.spec())
        # an *explicitly requested* tile depth/width that legalisation
        # demoted warns (once per compile): non-tracing users must not
        # silently lose what they asked for.  Plan-carried requests (tuner
        # candidates, cached plans) stay quiet here — the dataflow layer
        # emits the ChainDemoted/PlaneDemoted trace events for those.
        if (time_tile is not None and time_tile > 1
                and graph.time_tile < time_tile):
            reason = update_demote or dataflow.chain_split_reason(
                p, [list(r.ops) for r in graph.regions])
            warnings.warn(
                f"time_tile={time_tile} demoted to effective "
                f"{graph.time_tile} for {p.name!r}: {reason}",
                TileDemotionWarning, stacklevel=4)
        if (plane_tile is not None and plane_tile > 1
                and graph.plane_tile < plane_tile):
            reason = dataflow.plane_split_reason(p, plane_tile, plan_grid)
            warnings.warn(
                f"plane_tile={plane_tile} demoted to effective "
                f"{graph.plane_tile} for {p.name!r}: {reason}",
                TileDemotionWarning, stacklevel=4)
        # chain-accumulated when the graph temporal-blocks: the fused-loop
        # carry must cover what the chained kernels slice per sweep
        group_halos = graph.group_halos()

    shard = None
    if mesh is not None:
        # halo inference per kernel is shared by the shard spec and the
        # time-loop carry sizing — compute it once (stream plans produced
        # theirs above, ghost-exact and chain-deepened)
        if group_halos is None:
            group_halos = [infer_halo(p, grp) for grp in plan.groups]
        shard = make_shard_spec(p, plan, grid, mesh, mesh_axes,
                                group_halos=group_halos,
                                stream_axis=stream_axis)

    time_spec = None
    if steps is not None:
        if update is None:
            raise ValueError("steps=N requires an update(fields, outputs) "
                             "rule to close the time loop")
        time_spec = plan_time_loop(p, plan, plan_grid, steps,
                                   carry_write=carry_write, shard=shard,
                                   group_halos=group_halos)
        if mesh is not None:
            raw = distribute.lower_sharded_time_loop(p, plan, grid,
                                                     time_spec, update, mesh,
                                                     graph=graph)
        elif plan.schedule == "stream":
            raw = lower_stream.lower_time_loop(p, plan, grid, time_spec,
                                               update, graph=graph)
        elif backend == "pallas":
            raw = lower_pallas.lower_time_loop(p, plan, grid, time_spec,
                                               update)
        else:
            raw = lower_jnp.lower_time_loop(p, backend.removeprefix("jnp_"),
                                            time_spec, update)
    elif mesh is not None:
        raw = distribute.lower_sharded(p, plan, grid, shard, mesh,
                                       graph=graph)
    elif plan.schedule == "stream":
        raw = lower_stream.lower(p, plan, grid, graph=graph)
    elif backend == "pallas":
        raw = lower_pallas.lower(p, plan, grid)
    else:
        raw = lower_jnp.lower(p, mode=backend.removeprefix("jnp_"))

    fn = jax.jit(raw) if jit else raw
    if steps is not None:
        metrics.counter("compile.fused_loops").inc()
    if tracer.enabled:
        eff_tt = (plan.stream.time_tile if plan.stream is not None
                  else plan.time_tile)
        eff_pt = (plan.stream.plane_tile if plan.stream is not None
                  else plan.plane_tile)
        sp.set(schedule=plan.schedule, time_tile=int(eff_tt),
               plane_tile=int(eff_pt), steps=steps,
               mesh=None if mesh is None else dict(mesh.shape))
        if o.plan is None:
            # this compile *chose* a plan (heuristic or tuned); compiles
            # handed an explicit plan= (tuner candidates, cached serving
            # plans) did not decide anything worth announcing
            rec = tuned_rec or {}
            tracer.emit(PlanChosen(
                program=p.name, backend=backend, schedule=plan.schedule,
                strategy=strategy, label=rec.get("label", "auto_plan"),
                time_tile=int(eff_tt), plane_tile=int(eff_pt),
                modeled_us=rec.get("modeled_us"),
                measured_us=rec.get("us_fused") or rec.get("us_single"),
                roofline_fraction=rec.get("roofline_fraction")))
    return CompiledStencil(program=p, plan=plan, grid=grid, _fn=fn,
                           jitted=jit, time_spec=time_spec, shard=shard)


def run_time_loop(ex: CompiledStencil, fields: dict, scalars: dict,
                  coeffs: dict, steps: int, update) -> dict:
    """Simple host-side time loop; ``update(fields, outputs) -> fields``."""
    for _ in range(steps):
        out = ex(fields, scalars, coeffs)
        fields = update(fields, out)
    return fields
