"""End-to-end driver: Program -> DataflowPlan -> compiled executable.

The user-facing API (the role PSyclone's code-generation entry point plays):

    prog = pw_advection()
    ex = compile_program(prog, grid=(64, 64, 128), backend="pallas")
    out = ex(fields, scalars, coeffs)          # dict of output arrays

Backends:
    "pallas"     generated Pallas dataflow kernels (the paper's contribution)
    "jnp_fused"  XLA-fused full-array execution  (DaCe-role baseline)
    "jnp_naive"  op-at-a-time full-array execution (unoptimised-HLS role)
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp

from . import lower_jnp, lower_pallas
from .ir import Program
from .schedule import DataflowPlan, auto_plan


@dataclasses.dataclass
class CompiledStencil:
    program: Program
    plan: DataflowPlan
    grid: tuple
    _fn: object
    jitted: bool

    def __call__(self, fields: Mapping, scalars: Mapping | None = None,
                 coeffs: Mapping | None = None) -> dict:
        return self._fn(dict(fields), dict(scalars or {}), dict(coeffs or {}))


def compile_program(p: Program, grid, *, backend: str = "pallas",
                    plan: DataflowPlan | None = None, jit: bool = True,
                    interpret: bool = True, dtype: str = "float32",
                    strategy: str = "auto") -> CompiledStencil:
    grid = tuple(int(g) for g in grid)
    if len(grid) != p.ndim:
        raise ValueError(f"grid rank {len(grid)} != program ndim {p.ndim}")
    if plan is None:
        plan = auto_plan(p, grid, backend=backend, interpret=interpret,
                         dtype=dtype, strategy=strategy)
    plan.backend = backend

    if backend == "pallas":
        raw = lower_pallas.lower(p, plan, grid)
    elif backend == "jnp_fused":
        raw = lower_jnp.lower(p, mode="fused")
    elif backend == "jnp_naive":
        raw = lower_jnp.lower(p, mode="naive")
    else:
        raise ValueError(f"unknown backend {backend!r}")

    fn = jax.jit(raw) if jit else raw
    return CompiledStencil(program=p, plan=plan, grid=grid, _fn=fn, jitted=jit)


def run_time_loop(ex: CompiledStencil, fields: dict, scalars: dict,
                  coeffs: dict, steps: int, update) -> dict:
    """Simple host-side time loop; ``update(fields, outputs) -> fields``."""
    for _ in range(steps):
        out = ex(fields, scalars, coeffs)
        fields = update(fields, out)
    return fields
