"""End-to-end driver: Program -> DataflowPlan -> compiled executable.

The user-facing API (the role PSyclone's code-generation entry point plays):

    prog = pw_advection()
    ex = compile_program(prog, grid=(64, 64, 128), backend="pallas")
    out = ex(fields, scalars, coeffs)          # dict of output arrays

Backends:
    "pallas"     generated Pallas dataflow kernels (the paper's contribution)
    "jnp_fused"  XLA-fused full-array execution  (DaCe-role baseline)
    "jnp_naive"  op-at-a-time full-array execution (unoptimised-HLS role)
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp

from . import lower_jnp, lower_pallas
from .ir import Program
from .schedule import DataflowPlan, TimeLoopSpec, auto_plan, plan_time_loop


@dataclasses.dataclass
class CompiledStencil:
    program: Program
    plan: DataflowPlan
    grid: tuple
    _fn: object
    jitted: bool
    # fused time loop (``steps=N``): the executable returns the *final
    # fields* after N on-device iterations instead of one step's outputs
    time_spec: TimeLoopSpec | None = None

    def __call__(self, fields: Mapping, scalars: Mapping | None = None,
                 coeffs: Mapping | None = None) -> dict:
        return self._fn(dict(fields), dict(scalars or {}), dict(coeffs or {}))


def compile_program(p: Program, grid, *, backend: str = "pallas",
                    plan: DataflowPlan | None = None, jit: bool = True,
                    interpret: bool = True, dtype: str = "float32",
                    strategy: str = "auto", steps: int | None = None,
                    update=None, carry_write: str | None = None,
                    tune_config=None, plan_cache=None) -> CompiledStencil:
    """Compile ``p`` for ``grid``.

    With ``steps=N`` and an ``update(fields, outputs) -> fields`` rule, the
    whole time loop is lowered into the compiled program (one ``jax.jit``
    dispatch per call): the loop carry keeps the input fields resident and
    pre-padded on device, and ``update`` is traced into the loop body.  The
    executable then maps initial fields to the fields after N steps —
    exactly N iterations of :func:`run_time_loop`, without N dispatches,
    N ``jnp.pad`` rounds, or N host round trips.

    ``strategy="tuned"`` replaces the ``auto_plan`` heuristic with the
    measured search of :mod:`repro.core.tune`: the persistent plan cache is
    consulted first (a hit compiles the stored plan with zero timed runs);
    on a miss the tuner measures model-pruned candidates and persists the
    winner.  ``tune_config`` (:class:`~repro.core.tune.TuneConfig`) and
    ``plan_cache`` (:class:`~repro.core.tune.PlanCache`) override the search
    knobs and cache location.  ``carry_write=None`` defers to the tuned
    style (or ``"repad"`` under any other strategy).
    """
    grid = tuple(int(g) for g in grid)
    if len(grid) != p.ndim:
        raise ValueError(f"grid rank {len(grid)} != program ndim {p.ndim}")
    tuned_cw = None
    if plan is None:
        if strategy == "tuned":
            from . import tune
            res = tune.get_tuned_plan(p, grid, backend=backend,
                                      interpret=interpret, dtype=dtype,
                                      update=update, config=tune_config,
                                      cache=plan_cache)
            plan, tuned_cw = res.plan, res.carry_write
        else:
            plan = auto_plan(p, grid, backend=backend, interpret=interpret,
                             dtype=dtype, strategy=strategy, steps=steps)
    plan.backend = backend
    if carry_write is None:
        carry_write = tuned_cw or "repad"

    time_spec = None
    if steps is not None:
        if update is None:
            raise ValueError("steps=N requires an update(fields, outputs) "
                             "rule to close the time loop")
        time_spec = plan_time_loop(p, plan, grid, steps,
                                   carry_write=carry_write)
        if backend == "pallas":
            raw = lower_pallas.lower_time_loop(p, plan, grid, time_spec,
                                               update)
        elif backend in ("jnp_fused", "jnp_naive"):
            raw = lower_jnp.lower_time_loop(p, backend.removeprefix("jnp_"),
                                            time_spec, update)
        else:
            raise ValueError(f"unknown backend {backend!r}")
    elif backend == "pallas":
        raw = lower_pallas.lower(p, plan, grid)
    elif backend == "jnp_fused":
        raw = lower_jnp.lower(p, mode="fused")
    elif backend == "jnp_naive":
        raw = lower_jnp.lower(p, mode="naive")
    else:
        raise ValueError(f"unknown backend {backend!r}")

    fn = jax.jit(raw) if jit else raw
    return CompiledStencil(program=p, plan=plan, grid=grid, _fn=fn,
                           jitted=jit, time_spec=time_spec)


def run_time_loop(ex: CompiledStencil, fields: dict, scalars: dict,
                  coeffs: dict, steps: int, update) -> dict:
    """Simple host-side time loop; ``update(fields, outputs) -> fields``."""
    for _ in range(steps):
        out = ex(fields, scalars, coeffs)
        fields = update(fields, out)
    return fields
