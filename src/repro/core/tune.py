"""Auto-tuning layer: measured search over the DataflowPlan space.

This is the loop the paper's headline rests on — the *tooling*, not the
programmer, picks the dataflow structure (§3: the transformation space is
searched automatically; the 14-100x over Vitis-style baselines comes from
that search, not from any single heuristic).  :func:`~repro.core.schedule.
auto_plan` is the one-shot heuristic seed; this module closes the loop:

1. **generate** candidates over the plan knobs — fuse strategy (``fused`` /
   ``per_field`` / ``auto``), block shape (lane-quantised on the last axis),
   ``carry_write`` style, and dtype;
2. **prune** with the static models — the steps-aware
   :func:`~repro.core.schedule.vmem_cost` drops plans whose carry-enlarged
   windows exceed the VMEM budget, and
   :func:`~repro.analysis.stencil_roofline.model_plan` ranks the rest so
   only the most promising ``max_measured`` candidates pay for a run;
3. **measure** the survivors on-device (warm-up + best-of-k with
   ``block_until_ready``, the same discipline as
   ``benchmarks/fig4_throughput.py``), in both single-step and fused
   ``steps=N`` modes when an update rule is available;
4. **persist** the winner in a JSON plan cache keyed by (program
   fingerprint, grid, backend, jax version, interpret flag), so
   ``compile_program(..., strategy="tuned")`` is a pure cache hit — zero
   measured runs — after the first tune.

The ``auto_plan`` seed is always measured as the baseline candidate, so the
tuned plan is never slower than the heuristic *on the tuner's own
measurements* — the search can only keep or beat the seed.

The measurement timer is injectable (``TuneConfig.timer``) so tests can
drive the search with fake timings: same measurements imply the same
winning plan.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import os
import threading
import time
import uuid
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import hw
from ..obs.events import CacheHit, CacheMiss, PlanChosen
from ..obs.metrics import MetricsRegistry, global_metrics
from ..obs.trace import current_tracer
from .ir import Program
from .schedule import (PLAN_SCHEMA_VERSION, DataflowPlan, auto_plan,
                       mesh_fingerprint, plan_from_dict, plan_to_dict,
                       program_fingerprint, vmem_cost)

__all__ = [
    "TuneConfig", "PlanCache", "TuneResult", "cache_key", "tune_plan",
    "get_tuned_plan", "default_cache_path", "make_serve_record",
    "read_serve_record",
]

#: Environment variable overriding the default plan-cache location.
PLAN_CACHE_ENV = "REPRO_PLAN_CACHE"

#: On-disk plan-cache schema version.  Bumped together with
#: :data:`~repro.core.schedule.PLAN_SCHEMA_VERSION` whenever serialised
#: plans gain fields whose absence would change behaviour (v2: the
#: ``schedule`` axis + ``StreamSpec``; v3: temporal blocking — ``time_tile``
#: on the plan and the effective chain depth on the stream spec; v4:
#: spatial unrolling — ``plane_tile`` on the plan and the effective sweep
#: width on the stream spec).  A cache written by another version is
#: treated as a **miss** — re-tuning is cheap, silently misreading a stale
#: record is not — and the next store rewrites the file at the current
#: version.
CACHE_SCHEMA_VERSION = 4


def default_cache_path() -> str:
    env = os.environ.get(PLAN_CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "stencil_hmls",
                        "plan_cache.json")


@dataclasses.dataclass
class TuneConfig:
    """Knobs of one tuning run (all defaults are CI-smoke sized)."""

    steps: int = 3              # fused-loop depth measured per candidate
    warmup: int = 1             # un-timed calls before measuring (jit compile)
    repeats: int = 3            # best-of-k timed calls
    max_measured: int = 8       # model-ranked candidates that pay for a run
    vmem_budget: int = hw.VMEM_PLAN_BUDGET
    strategies: tuple = ("auto", "fused", "per_field")
    carry_writes: tuple = ("repad", "inplace")
    # temporal-blocking depths tried for stream candidates (fused-loop mode
    # only — single-step sweeps have no update rule to chain through).
    # Depths that legalise to the same effective chain dedup to one run.
    time_tiles: tuple = (1, 2, 4)
    # spatial-unrolling widths tried for stream candidates (single-step and
    # fused-loop alike — a wider sweep step needs no update rule).  Widths
    # the legaliser demotes to the same effective P dedup to one run.
    plane_tiles: tuple = (1, 2, 4)
    dtypes: tuple | None = None   # None = the dtype compile_program asked for
    seed: int = 0               # synthetic measurement data
    # the cache key identifies the *problem*, not the search effort: a plan
    # tuned with a shallow config is served to later deeper-config compiles.
    # Set force_retune to bypass the lookup and overwrite the cached entry
    # with this config's winner.
    force_retune: bool = False
    # timer(fn) -> seconds; None = warm-up + best-of-k wall clock.  Tests
    # inject deterministic fakes here (and count invocations to prove cache
    # hits measure nothing).
    timer: Callable | None = None


class PlanCache:
    """Persistent JSON store of tuned plans.

    ``path=None`` keeps the cache in memory only (tests); the default path
    is ``$REPRO_PLAN_CACHE`` or ``~/.cache/stencil_hmls/plan_cache.json``.
    File format: ``{"version": CACHE_SCHEMA_VERSION, "entries":
    {cache_key: record}}`` where a record holds the serialised plan, its
    ``carry_write`` style, and the tuning measurements (see
    :func:`tune_plan`).  Files written by a different schema version (or
    unreadable ones) load as empty: every lookup misses, and the first
    store rewrites the file at the current version.

    Every ``lookup`` counts itself into the cache's own metrics registry
    (``cache.metrics``, counters ``hits``/``misses``) and mirrors into the
    process-wide registry as ``plan_cache.hits``/``plan_cache.misses`` —
    the *cache* owns its hit accounting, callers just read the counters.
    """

    def __init__(self, path: str | None = "auto"):
        self.path = default_cache_path() if path == "auto" else path
        self._mem: dict = {}
        self._lock = threading.Lock()
        self.metrics = MetricsRegistry()

    @property
    def hits(self) -> int:
        return self.metrics.counter("hits").value

    @property
    def misses(self) -> int:
        return self.metrics.counter("misses").value

    def _load(self) -> dict:
        if self.path and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    doc = json.load(f)
                if (doc.get("version") == CACHE_SCHEMA_VERSION
                        and isinstance(doc.get("entries"), dict)):
                    return doc
            except (json.JSONDecodeError, OSError):
                pass
        return {"version": CACHE_SCHEMA_VERSION, "entries": {}}

    def lookup(self, key: str) -> dict | None:
        with self._lock:
            rec = self._mem.get(key)
        if rec is None:
            rec = self._load()["entries"].get(key)
        name = "hits" if rec is not None else "misses"
        self.metrics.counter(name).inc()
        global_metrics().counter(f"plan_cache.{name}").inc()
        return rec

    def store(self, key: str, record: dict) -> None:
        """Persist ``record`` under ``key`` — safe under concurrent writers.

        Two tuners (or two serving engines) sharing one cache file must not
        clobber each other's entries, so the rewrite is an atomic
        read-merge-replace: an advisory ``flock`` on ``<path>.lock``
        serialises writers (across objects *and* processes), each writer
        re-reads the file under the lock, layers its own entries on top,
        writes to a per-writer unique temp file, and ``os.replace``s it in
        — readers never see a torn or truncated JSON, and no store loses
        another writer's entries.  On platforms without ``fcntl`` the lock
        degrades to best-effort merge-on-write.
        """
        with self._lock:
            self._mem[key] = record
            if not self.path:
                return
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            with self._file_lock():
                # re-read under the lock so entries written by other
                # processes/threads since our last load survive the rewrite
                doc = self._load()
                doc["entries"].update(self._mem)
                tmp = (f"{self.path}.{os.getpid()}."
                       f"{uuid.uuid4().hex[:8]}.tmp")
                try:
                    with open(tmp, "w") as f:
                        json.dump(doc, f, indent=2)
                    os.replace(tmp, self.path)
                finally:
                    if os.path.exists(tmp):
                        os.unlink(tmp)

    @contextlib.contextmanager
    def _file_lock(self):
        try:
            import fcntl
        except ImportError:  # non-POSIX: best-effort merge-on-write
            yield
            return
        with open(f"{self.path}.lock", "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)


def _mesh_tag(mesh, mesh_axes) -> str:
    """Stable encoding of the mesh topology a plan was tuned under (the
    shared :func:`~repro.core.schedule.mesh_fingerprint`): topologies of
    the same device count (2x4 vs 4x2, or different grid-axis assignments)
    shard different local blocks and measure different collectives — their
    tuned plans must not serve each other."""
    return mesh_fingerprint(mesh, mesh_axes)


def cache_key(p: Program, grid: Sequence[int], backend: str,
              interpret: bool, dtype: str = "float32",
              mode: str = "loop", mesh=None, mesh_axes=None) -> str:
    """Tuned plans transfer only between identical search problems: same
    program semantics (boundary conditions included, via the fingerprint),
    grid, backend, jax version, interpret flag, requested dtype, mesh
    topology, and tuning mode (``"loop"`` = ranked by the fused ``steps=N``
    measurement with carry-aware VMEM pruning, ``"single"`` = single-step
    only) — a single-step winner must not silently serve a fused compile,
    nor a 2x2 winner a 4x1 mesh."""
    return "|".join([
        program_fingerprint(p),
        "grid=" + "x".join(str(int(g)) for g in grid),
        f"backend={backend}",
        f"jax={jax.__version__}",
        f"interpret={int(bool(interpret))}",
        f"dtype={dtype}",
        f"mode={mode}",
        f"mesh={_mesh_tag(mesh, mesh_axes)}",
    ])


@dataclasses.dataclass
class _Candidate:
    plan: DataflowPlan
    carry_write: str
    label: str
    modeled_s: float = float("inf")
    us_single: float | None = None
    us_fused: float | None = None

    def score(self) -> float:
        if self.us_fused is not None:
            return self.us_fused
        return self.us_single if self.us_single is not None else float("inf")


@dataclasses.dataclass
class TuneResult:
    plan: DataflowPlan
    carry_write: str
    key: str
    record: dict
    cache_hit: bool
    # every measured candidate, winner-first sorted by score (empty on hit)
    measured: list = dataclasses.field(default_factory=list)

    @property
    def baseline(self) -> _Candidate | None:
        """The measured ``auto_plan`` heuristic seed itself (exact label:
        the ``auto_plan/cw=...`` variants are different candidates)."""
        for c in self.measured:
            if c.label == "auto_plan":
                return c
        return None


# --------------------------------------------------------------------------
# candidate generation
# --------------------------------------------------------------------------

def _block_candidates(p: Program, grid: Sequence[int]) -> list:
    """Lane-quantised last axis (x128 bursts), coarse sweep elsewhere."""
    ndim = p.ndim
    grid = [int(g) for g in grid]
    per_axis = []
    for ax in range(ndim - 1):
        opts = {grid[ax]}
        for c in (8, 32):
            if c < grid[ax]:
                opts.add(c)
        per_axis.append(sorted(opts))
    lane_opts = {min(grid[-1], hw.LANE)}
    if grid[-1] > hw.LANE:
        lane_opts.add(min(grid[-1], 2 * hw.LANE))
    per_axis.append(sorted(lane_opts))
    return [tuple(b) for b in itertools.product(*per_axis)]


def _behaviour_key(plan: DataflowPlan, carry_write: str, backend: str,
                   with_loop: bool):
    """Two candidates with the same key lower to the same executable."""
    cw = carry_write if with_loop else None
    if backend != "pallas":
        # the jnp lowerings ignore groups, block shape and dtype
        return (cw,)
    if plan.schedule == "stream":
        # streams ignore block shape; the legalised regions decide the
        # kernels (two strategies whose groups legalise identically tie).
        # The *effective* chain depth matters only in fused-loop mode —
        # single-step sweeps never chain — and requested depths demoted to
        # the same effective depth lower identically.
        eff = (plan.stream.time_tile if plan.stream is not None
               else plan.time_tile)
        # the effective sweep width matters in both modes — spatial
        # unrolling needs no update rule — and requested widths demoted to
        # the same effective P lower identically.
        eff_p = (plan.stream.plane_tile if plan.stream is not None
                 else plan.plane_tile)
        regions = (plan.stream.regions if plan.stream is not None
                   else tuple(tuple(g) for g in plan.groups))
        return ("stream", regions, plan.dtype, cw,
                int(eff) if with_loop else 1, int(eff_p))
    return (tuple(tuple(g) for g in plan.groups), tuple(plan.block),
            plan.dtype, cw)


def _candidates(p: Program, grid, backend: str, interpret: bool,
                dtype: str, cfg: TuneConfig, with_loop: bool) -> list:
    ndim = p.ndim
    out: list[_Candidate] = []
    seen: set = set()

    def add(plan, cw, label):
        k = _behaviour_key(plan, cw, backend, with_loop)
        if k in seen:
            return
        seen.add(k)
        out.append(_Candidate(plan=plan, carry_write=cw, label=label))

    carry_writes = cfg.carry_writes if with_loop else ("repad",)
    steps = cfg.steps if with_loop else None
    # the heuristic seed is always candidate 0: the tuned plan can only keep
    # or beat it on the tuner's own measurements
    base = auto_plan(p, grid, backend=backend, interpret=interpret,
                     dtype=dtype, vmem_budget=cfg.vmem_budget, steps=steps)
    add(base, "repad", "auto_plan")
    for cw in carry_writes:
        add(base, cw, f"auto_plan/cw={cw}")
    blocks = _block_candidates(p, grid)
    for strat, dt in itertools.product(cfg.strategies, cfg.dtypes or (dtype,)):
        plan0 = auto_plan(p, grid, backend=backend, interpret=interpret,
                          dtype=dt, strategy=strat,
                          vmem_budget=cfg.vmem_budget, steps=steps)
        for blk, cw in itertools.product(blocks, carry_writes):
            plan = dataclasses.replace(plan0, block=tuple(blk),
                                       groups=[list(g) for g in plan0.groups])
            add(plan, cw, f"{strat}/block={'x'.join(map(str, blk))}/cw={cw}"
                          + (f"/dtype={dt}" if dt != "float32" else ""))
        # the stream schedule is a first-class plan dimension: one
        # shift-register candidate per fuse strategy (block shape does not
        # apply — the non-stream axes are resident whole) x temporal-chain
        # depth (fused-loop mode only; depths legalised to the same
        # effective chain dedup via the behaviour key)
        if backend == "pallas" and ndim >= 2:
            tiles = tuple(cfg.time_tiles) if with_loop else (1,)
            ptiles = tuple(cfg.plane_tiles) or (1,)
            for tt, pt in itertools.product(tiles, ptiles):
                plan_s = auto_plan(p, grid, backend=backend,
                                   interpret=interpret, dtype=dt,
                                   strategy=strat,
                                   vmem_budget=cfg.vmem_budget, steps=steps,
                                   schedule="stream", time_tile=int(tt),
                                   plane_tile=int(pt))
                tag = f"/T={int(tt)}" if int(tt) > 1 else ""
                tag += f"/P={int(pt)}" if int(pt) > 1 else ""
                for cw in carry_writes:
                    add(plan_s, cw, f"stream/{strat}{tag}/cw={cw}"
                                   + (f"/dtype={dt}" if dt != "float32"
                                      else ""))
    return out


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def _synth_data(p: Program, grid, seed: int = 0):
    rng = np.random.default_rng(seed)
    grid = tuple(int(g) for g in grid)
    fields = {f: jnp.asarray(rng.normal(size=grid).astype(np.float32) * 0.1)
              for f in p.input_fields()}
    scalars = {s: jnp.float32(0.05) for s in p.scalars}
    coeffs = {c: jnp.asarray(
        (np.abs(rng.normal(size=(grid[ax],))) + 0.5).astype(np.float32))
        for c, ax in p.coeffs.items()}
    return fields, scalars, coeffs


def _default_timer_factory(warmup: int, repeats: int) -> Callable:
    def timer(fn):
        out = None
        for _ in range(max(1, warmup)):
            out = fn()                      # jit compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, repeats)):    # best-of-k (CPU noise)
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best
    return timer


def _roofline_fraction(cand: _Candidate, steps: int | None) -> float | None:
    """Achieved fraction of the plan model's prediction:
    ``modeled_time / measured_time`` for the mode the candidate is ranked
    by (fused ``steps=N`` when measured, else single-step).  ``None`` when
    the candidate was never measured or the model degenerated."""
    meas_us = cand.us_fused if cand.us_fused is not None else cand.us_single
    if meas_us is None or meas_us <= 0:
        return None
    if not (cand.modeled_s > 0) or cand.modeled_s == float("inf"):
        return None
    mult = (steps or 1) if cand.us_fused is not None else 1
    return (cand.modeled_s * 1e6 * mult) / meas_us


def _measure(p, grid, cand: _Candidate, data, update, cfg: TuneConfig,
             timer, mesh=None, mesh_axes=None) -> None:
    # deferred: pipeline imports tune
    from .pipeline import CompileOptions, compile_program
    fields, scalars, coeffs = data
    ex = compile_program(p, grid, options=CompileOptions(
        backend=cand.plan.backend, plan=cand.plan,
        mesh=mesh, mesh_axes=mesh_axes))
    cand.us_single = timer(lambda: ex(fields, scalars, coeffs)) * 1e6
    if update is not None:
        exN = compile_program(p, grid, options=CompileOptions(
            backend=cand.plan.backend, plan=cand.plan, steps=cfg.steps,
            update=update, carry_write=cand.carry_write,
            mesh=mesh, mesh_axes=mesh_axes))
        cand.us_fused = timer(lambda: exN(fields, scalars, coeffs)) * 1e6


# --------------------------------------------------------------------------
# the tuning loop
# --------------------------------------------------------------------------

def tune_plan(p: Program, grid, *, backend: str = "pallas",
              interpret: bool = True, dtype: str = "float32",
              update=None, config: TuneConfig | None = None,
              cache: PlanCache | None = None,
              mesh=None, mesh_axes=None) -> TuneResult:
    """Search the plan space by measurement and persist the winner.

    Generates candidates, prunes with the corrected VMEM cost and the
    roofline plan model, measures the survivors (single-step always; fused
    ``steps=N`` when ``update`` is given, which is also what the winner is
    ranked by), and stores the winning record under :func:`cache_key`.

    With ``mesh``/``mesh_axes`` the search tunes a *sharded* plan:
    candidate blocks are generated and VMEM-priced against the per-shard
    local grid, every measurement runs the real ``shard_map`` executable
    (halo exchange included), and the cache key carries the mesh topology.
    """
    # deferred: repro.analysis imports core IR modules, which would re-enter
    # this package's __init__ at import time
    from ..analysis.stencil_roofline import model_plan
    cfg = config or TuneConfig()
    cache = PlanCache() if cache is None else cache
    grid = tuple(int(g) for g in grid)
    plan_grid = grid
    if mesh is not None:
        from .schedule import normalize_mesh_axes, shard_local_grid
        if mesh_axes is None:
            mesh_axes = tuple(mesh.axis_names)
        mesh_axes = normalize_mesh_axes(mesh_axes, p.ndim)
        plan_grid = shard_local_grid(grid, mesh, mesh_axes)
    timer0 = cfg.timer or _default_timer_factory(cfg.warmup, cfg.repeats)

    def timer(fn):
        # every on-device timing is counted process-wide: cache-hit tests
        # assert a zero delta here instead of monkeypatching the timer
        global_metrics().counter("tune.timed_runs").inc()
        return timer0(fn)

    with_loop = update is not None
    tracer = current_tracer()
    global_metrics().counter("tune.runs").inc()

    # stream candidates compete under a mesh too: each shard sweeps its
    # local block (with exact neighbour ghost planes when the stream axis
    # itself is sharded), so ``plan_grid`` prices VMEM and the roofline
    # per shard and the measurement runs the real shard_map executable
    cands = _candidates(p, plan_grid, backend, interpret, dtype, cfg,
                        with_loop)
    baseline, rest = cands[0], cands[1:]

    # prune: VMEM feasibility on the local block (carry-aware when tuning
    # the fused loop), then modeled-time ranking; the baseline never pays
    # for either filter
    steps_for_cost = cfg.steps if with_loop else None
    feasible = []
    for c in rest:
        if (c.plan.backend == "pallas"
                and vmem_cost(p, c.plan, plan_grid, steps=steps_for_cost)
                > cfg.vmem_budget):
            continue
        feasible.append(c)
    for c in [baseline] + feasible:
        c.modeled_s = model_plan(p, c.plan, plan_grid)
    feasible.sort(key=lambda c: c.modeled_s)
    survivors = [baseline] + feasible[:max(0, cfg.max_measured - 1)]

    data = _synth_data(p, grid, seed=cfg.seed)
    with tracer.span("tune", program=p.name, backend=backend,
                     mode="loop" if with_loop else "single",
                     candidates=len(cands), measured=len(survivors)):
        for c in survivors:
            with tracer.span("tune.candidate", program=p.name,
                             label=c.label) as csp:
                _measure(p, grid, c, data, update, cfg, timer,
                         mesh=mesh, mesh_axes=mesh_axes)
                csp.set(modeled_us=c.modeled_s * 1e6,
                        us_single=c.us_single, us_fused=c.us_fused,
                        roofline_fraction=_roofline_fraction(
                            c, cfg.steps if with_loop else None))

    order = sorted(range(len(survivors)),
                   key=lambda i: (survivors[i].score(), i))
    winner = survivors[order[0]]

    key = cache_key(p, grid, backend, interpret, dtype,
                    "loop" if with_loop else "single",
                    mesh=mesh, mesh_axes=mesh_axes)
    record = {
        "plan": plan_to_dict(winner.plan),
        "carry_write": winner.carry_write,
        "label": winner.label,
        # effective temporal-chain depth of the winner (1 = unchained)
        "time_tile": int(winner.plan.stream.time_tile
                         if winner.plan.stream is not None
                         else winner.plan.time_tile),
        # effective sweep width of the winner (1 = plane-at-a-time)
        "plane_tile": int(winner.plan.stream.plane_tile
                          if winner.plan.stream is not None
                          else winner.plan.plane_tile),
        "us_single": winner.us_single,
        "us_fused": winner.us_fused,
        "baseline_us_single": baseline.us_single,
        "baseline_us_fused": baseline.us_fused,
        "modeled_us": winner.modeled_s * 1e6,
        # achieved fraction of the roofline plan model's prediction for the
        # winner (modeled/measured; tiny under CPU interpret — the tracked
        # quantity is its trend, see repro.obs.achieved)
        "roofline_fraction": _roofline_fraction(
            winner, cfg.steps if with_loop else None),
        "mesh": _mesh_tag(mesh, mesh_axes),
        "steps": cfg.steps if with_loop else None,
        "candidates": len(cands),
        "measured": len(survivors),
        "fingerprint": program_fingerprint(p),
        "jax_version": jax.__version__,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    cache.store(key, record)
    if tracer.enabled:
        tracer.emit(PlanChosen(
            program=p.name, backend=backend,
            schedule=winner.plan.schedule, strategy="tuned",
            label=winner.label, time_tile=record["time_tile"],
            plane_tile=record["plane_tile"], modeled_us=record["modeled_us"],
            measured_us=winner.score(),
            roofline_fraction=record["roofline_fraction"]))
    return TuneResult(plan=winner.plan, carry_write=winner.carry_write,
                      key=key, record=record, cache_hit=False,
                      measured=[survivors[i] for i in order])


def get_tuned_plan(p: Program, grid, *, backend: str = "pallas",
                   interpret: bool = True, dtype: str = "float32",
                   update=None, config: TuneConfig | None = None,
                   cache: PlanCache | None = None,
                   mesh=None, mesh_axes=None) -> TuneResult:
    """Cache-first entry point behind ``compile_program(strategy="tuned")``.

    A hit deserialises the stored plan and performs **zero** timed runs; a
    miss runs :func:`tune_plan` and persists the winner.  The key does not
    encode the search effort, so pass a config with ``force_retune=True``
    to re-search (and overwrite the entry) with different knobs.
    """
    cache = PlanCache() if cache is None else cache
    if mesh is not None:
        from .schedule import normalize_mesh_axes
        if mesh_axes is None:
            mesh_axes = tuple(mesh.axis_names)
        mesh_axes = normalize_mesh_axes(mesh_axes, p.ndim)
    key = cache_key(p, tuple(int(g) for g in grid), backend, interpret,
                    dtype, "loop" if update is not None else "single",
                    mesh=mesh, mesh_axes=mesh_axes)
    rec = None if (config is not None and config.force_retune) \
        else cache.lookup(key)
    tracer = current_tracer()
    if rec is not None:
        if tracer.enabled:
            tracer.emit(CacheHit(cache="tuned_plan", key=key))
        return TuneResult(plan=plan_from_dict(rec["plan"]),
                          carry_write=rec.get("carry_write", "repad"),
                          key=key, record=rec, cache_hit=True)
    if tracer.enabled:
        tracer.emit(CacheMiss(cache="tuned_plan", key=key))
    return tune_plan(p, grid, backend=backend, interpret=interpret,
                     dtype=dtype, update=update, config=config, cache=cache,
                     mesh=mesh, mesh_axes=mesh_axes)


# --------------------------------------------------------------------------
# Serving-layer executor records (repro.serve's slice of the plan cache)
# --------------------------------------------------------------------------

def make_serve_record(plan: DataflowPlan, carry_write: str,
                      bucket: Sequence[int], steps: int | None) -> dict:
    """Executor record the serving engine persists per compiled bucket: the
    plan the executable was built from plus enough metadata that a *fresh
    engine process* can rebuild the identical executable without planning,
    tuning, or guessing.  Schema-stamped like tuned-plan records — see
    :func:`read_serve_record`."""
    return {
        "kind": "serve_executor",
        "schema": PLAN_SCHEMA_VERSION,
        "plan": plan_to_dict(plan),
        "carry_write": carry_write,
        "bucket": [int(b) for b in bucket],
        "steps": None if steps is None else int(steps),
        "jax_version": jax.__version__,
        "stored_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def read_serve_record(rec: dict | None):
    """Decode a serving executor record: ``(plan, carry_write)``, or ``None``
    when the record is absent, malformed, or written under a different
    ``PLAN_SCHEMA_VERSION`` — a stale-schema record is a clean *miss* (the
    engine replans and overwrites), never a misdecoded plan."""
    if not isinstance(rec, dict) or rec.get("kind") != "serve_executor":
        return None
    if rec.get("schema") != PLAN_SCHEMA_VERSION:
        return None
    try:
        plan = plan_from_dict(rec["plan"])
    except (KeyError, TypeError, ValueError):
        return None
    return plan, rec.get("carry_write", "repad")
