"""Boundary-condition subsystem: one place that knows how halos are filled.

Every layer of the pipeline needs the same decision — what does an access
outside the domain read? — and before this module each backend hard-coded
the zero-halo convention.  Boundaries are declared per field on the IR
(:class:`~repro.core.ir.FieldDecl.boundary`) and the helpers here realise
them uniformly:

* ``"zero"``      out-of-domain reads return 0 (the IR's historical
                  convention; ``jnp.pad`` zero slabs, partial ``ppermute``
                  rings that leave edge shards zero-filled).
* ``"periodic"``  the domain is a torus: out-of-domain reads wrap around
                  (``jnp.roll`` / wrap-slices on a single device, full-ring
                  ``ppermute`` permutations across a mesh).

The same helpers serve the jnp lowerings (:func:`shift_field`), the Pallas
orchestrators (:func:`pad_field` builds carry/window buffers), the
distributed executor (:func:`ring_perms` builds the exchange permutation),
and the coefficient path (:func:`pad_coeff`), so a program declared
periodic runs a torus identically on all backends and any mesh.

Mixing boundaries inside one program is allowed with one validated rule
(:func:`validate_boundaries`): an op producing a *periodic* field may only
read periodic fields (and may only use per-level coefficients on a full
torus).  Without the rule, overlapped-tiling recompute in fused Pallas
groups could not reproduce the wraparound value of a periodic temp built
from zero-extended inputs, and backends would disagree at the edges.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

BOUNDARIES = ("zero", "periodic")


def validate_boundaries(p) -> None:
    """IR-level boundary checks (called from ``Program.validate``)."""
    for n, f in p.fields.items():
        if f.boundary not in BOUNDARIES:
            raise ValueError(
                f"field {n!r} has unknown boundary {f.boundary!r}; valid: "
                + ", ".join(repr(b) for b in BOUNDARIES))
    torus = all(f.boundary == "periodic" for f in p.fields.values())
    for op in p.ops:
        if p.fields[op.out].boundary != "periodic":
            continue
        for a in op.accesses():
            if p.fields[a.field].boundary != "periodic":
                raise ValueError(
                    f"op {op.name or op.out!r} produces periodic field "
                    f"{op.out!r} but reads zero-boundary field {a.field!r}; "
                    "a periodic field's wraparound values cannot be "
                    "recomputed from zero-extended inputs")
        if op.coeff_refs() and not torus:
            raise ValueError(
                f"op {op.name or op.out!r} produces periodic field "
                f"{op.out!r} and reads per-level coefficients, but the "
                "program is not a full torus (coefficient wraparound is "
                "axis-global)")


def coeff_mode(p) -> str:
    """How 1-D coefficient arrays extend beyond the domain: they wrap only
    on a full torus (every field periodic), zero-extend otherwise."""
    return "periodic" if p.is_torus() else "zero"


def pad_field(x: jnp.ndarray, lo: Sequence[int], hi: Sequence[int],
              boundary: str, align_hi: Sequence[int] | None = None
              ) -> jnp.ndarray:
    """Pad ``x`` with halo slabs per ``boundary`` plus a zero alignment slab.

    ``lo``/``hi`` are the per-axis halo widths; ``align_hi`` (optional) is
    extra hi-side tile-alignment padding, always zero-filled — alignment
    positions are never read by in-domain consumers, only cropped or
    masked, so they need no wraparound values.
    """
    ndim = x.ndim
    align_hi = tuple(align_hi) if align_hi is not None else (0,) * ndim
    if boundary == "zero":
        pads = [(int(lo[a]), int(hi[a]) + int(align_hi[a]))
                for a in range(ndim)]
        return jnp.pad(x, pads)
    if boundary != "periodic":
        raise ValueError(f"unknown boundary {boundary!r}")
    for ax in range(ndim):
        l, h, al = int(lo[ax]), int(hi[ax]), int(align_hi[ax])
        if l == 0 and h == 0 and al == 0:
            continue
        n = x.shape[ax]
        if l > n or h > n:
            raise ValueError(
                f"periodic halo ({l},{h}) exceeds extent {n} on axis {ax}")
        pieces = []
        if l:
            pieces.append(jax.lax.slice_in_dim(x, n - l, n, axis=ax))
        pieces.append(x)
        if h:
            pieces.append(jax.lax.slice_in_dim(x, 0, h, axis=ax))
        if al:
            shp = list(x.shape)
            shp[ax] = al
            pieces.append(jnp.zeros(shp, x.dtype))
        x = jnp.concatenate(pieces, axis=ax)
    return x


def shift_field(x: jnp.ndarray, offset: Sequence[int], boundary: str
                ) -> jnp.ndarray:
    """``out[i] = x[i + offset]`` with out-of-domain reads per ``boundary``."""
    offset = tuple(int(o) for o in offset)
    if all(o == 0 for o in offset):
        return x
    if boundary == "periodic":
        axes = tuple(ax for ax, o in enumerate(offset) if o != 0)
        return jnp.roll(x, shift=tuple(-offset[ax] for ax in axes), axis=axes)
    if boundary != "zero":
        raise ValueError(f"unknown boundary {boundary!r}")
    h = max(abs(o) for o in offset)
    xp = jnp.pad(x, h)
    idx = tuple(slice(h + offset[ax], h + offset[ax] + x.shape[ax])
                for ax in range(x.ndim))
    return xp[idx]


def pad_coeff(c: jnp.ndarray, lo: int, hi: int, mode: str) -> jnp.ndarray:
    """Extend a replicated 1-D coefficient array by (lo, hi) per ``mode``.

    The wrap path gathers modular indices, so it stays correct even when
    the tile-alignment slab makes ``hi`` comparable to the array length.
    """
    lo, hi = int(lo), int(hi)
    if lo == 0 and hi == 0:
        return c
    if mode == "zero":
        return jnp.pad(c, (lo, hi))
    if mode != "periodic":
        raise ValueError(f"unknown boundary {mode!r}")
    n = c.shape[0]
    return c[jnp.arange(-lo, n + hi) % n]


def ring_perms(n: int, direction: int, periodic: bool) -> list:
    """``ppermute`` permutation shifting data by one shard.

    ``direction=+1`` sends each shard's slab to its right neighbour (fills
    *lo* halos), ``-1`` to its left (fills *hi* halos).  Periodic closes
    the ring; zero leaves the edge shard unreceiving, which ``ppermute``
    zero-fills — exactly the zero-halo convention at the global edge.
    """
    if direction not in (1, -1):
        raise ValueError(f"direction must be +1/-1, got {direction}")
    if periodic:
        return [(i, (i + direction) % n) for i in range(n)]
    if direction == 1:
        return [(i, i + 1) for i in range(n - 1)]
    return [(i + 1, i) for i in range(n - 1)]
