"""Frontend: builds stencil IR from plain Python — the PSyclone/Devito role.

The paper's DSLs lower Fortran/Python into the MLIR stencil dialect; here a
:class:`ProgramBuilder` plays that part.  Field handles support ``f[di,dj,dk]``
relative accesses and normal arithmetic, so a kernel is written essentially as
the maths appears in the source paper:

    b = ProgramBuilder("pw_advection", ndim=3)
    u, v, w = b.inputs("u", "v", "w")
    tzc1, tzc2 = b.scalars("tzc1", "tzc2")
    su = b.output("su")
    b.define(su, tzc1 * u[-1, 0, 0] * (w[-1, 0, 0] + w[0, 0, 0]) - ...)
    prog = b.build()
"""

from __future__ import annotations

from .ir import (Access, BinOp, BinOpKind, Cmp, CmpKind, CoeffRef, Const,
                 Expr, FieldDecl, FieldRole, Program, ScalarRef, Select,
                 StencilOp, UnOp, UnOpKind)

__all__ = [
    "ProgramBuilder", "ExprHandle", "FieldHandle", "CoeffHandle",
    "minimum", "maximum", "sqrt", "exp", "log", "tanh", "absolute", "where",
    "sign",
]


def _wrap(x) -> Expr:
    if isinstance(x, ExprHandle):
        return x.expr
    if isinstance(x, (int, float)):
        return Const(float(x))
    if isinstance(x, Expr):
        return x
    raise TypeError(f"cannot use {type(x)} in a stencil expression")


class ExprHandle:
    """Wraps an ir.Expr and overloads Python arithmetic."""

    __slots__ = ("expr",)
    __array_priority__ = 1000  # win against numpy scalars

    def __init__(self, expr: Expr):
        self.expr = expr

    # -- arithmetic ----------------------------------------------------
    def _bin(self, other, kind, swap=False):
        a, b = _wrap(self), _wrap(other)
        if swap:
            a, b = b, a
        return ExprHandle(BinOp(kind, a, b))

    def __add__(self, o):  return self._bin(o, BinOpKind.ADD)
    def __radd__(self, o): return self._bin(o, BinOpKind.ADD, swap=True)
    def __sub__(self, o):  return self._bin(o, BinOpKind.SUB)
    def __rsub__(self, o): return self._bin(o, BinOpKind.SUB, swap=True)
    def __mul__(self, o):  return self._bin(o, BinOpKind.MUL)
    def __rmul__(self, o): return self._bin(o, BinOpKind.MUL, swap=True)
    def __truediv__(self, o):  return self._bin(o, BinOpKind.DIV)
    def __rtruediv__(self, o): return self._bin(o, BinOpKind.DIV, swap=True)
    def __pow__(self, o):  return self._bin(o, BinOpKind.POW)
    def __neg__(self):     return ExprHandle(UnOp(UnOpKind.NEG, _wrap(self)))

    def __lt__(self, o): return ExprHandle(Cmp(CmpKind.LT, _wrap(self), _wrap(o)))
    def __le__(self, o): return ExprHandle(Cmp(CmpKind.LE, _wrap(self), _wrap(o)))
    def __gt__(self, o): return ExprHandle(Cmp(CmpKind.GT, _wrap(self), _wrap(o)))
    def __ge__(self, o): return ExprHandle(Cmp(CmpKind.GE, _wrap(self), _wrap(o)))


class FieldHandle:
    """A named grid field; ``f[offsets]`` yields an Access expression."""

    __slots__ = ("name", "ndim", "_builder")

    def __init__(self, name: str, ndim: int, builder: "ProgramBuilder"):
        self.name = name
        self.ndim = ndim
        self._builder = builder

    def __getitem__(self, offsets) -> ExprHandle:
        if self.ndim == 1 and isinstance(offsets, int):
            offsets = (offsets,)
        if not isinstance(offsets, tuple) or len(offsets) != self.ndim:
            raise ValueError(
                f"{self.name}[...] needs {self.ndim} integer offsets, got {offsets!r}")
        if not all(isinstance(o, int) for o in offsets):
            raise ValueError("stencil offsets must be compile-time integers")
        return ExprHandle(Access(self.name, tuple(offsets)))

    @property
    def c(self) -> ExprHandle:
        """Center access, f[0,...,0]."""
        return self[(0,) * self.ndim] if self.ndim > 1 else self[0]


class CoeffHandle:
    """1-D per-axis coefficient ('small data'); ``c[dk]`` reads at offset."""

    __slots__ = ("name", "axis")

    def __init__(self, name: str, axis: int):
        self.name = name
        self.axis = axis

    def __getitem__(self, off) -> ExprHandle:
        if not isinstance(off, int):
            raise ValueError("coefficient offsets must be compile-time ints")
        return ExprHandle(CoeffRef(self.name, off))

    @property
    def c(self) -> ExprHandle:
        return self[0]


# -- free functions mirroring arith/math dialect ops -----------------------

def minimum(a, b): return ExprHandle(BinOp(BinOpKind.MIN, _wrap(a), _wrap(b)))
def maximum(a, b): return ExprHandle(BinOp(BinOpKind.MAX, _wrap(a), _wrap(b)))
def sqrt(a):       return ExprHandle(UnOp(UnOpKind.SQRT, _wrap(a)))
def exp(a):        return ExprHandle(UnOp(UnOpKind.EXP, _wrap(a)))
def log(a):        return ExprHandle(UnOp(UnOpKind.LOG, _wrap(a)))
def tanh(a):       return ExprHandle(UnOp(UnOpKind.TANH, _wrap(a)))
def absolute(a):   return ExprHandle(UnOp(UnOpKind.ABS, _wrap(a)))
def sign(a):       return ExprHandle(UnOp(UnOpKind.SIGN, _wrap(a)))
def where(p, t, f):
    return ExprHandle(Select(_wrap(p), _wrap(t), _wrap(f)))


class ProgramBuilder:
    def __init__(self, name: str, ndim: int, boundary: str = "zero"):
        if ndim not in (1, 2, 3):
            raise ValueError("ndim must be 1..3")
        self.name = name
        self.ndim = ndim
        self.boundary = boundary      # default for every declared field
        self._fields: dict = {}
        self._scalars: list = []
        self._coeffs: dict = {}
        self._ops: list = []

    # -- declarations ---------------------------------------------------
    def input(self, name: str, boundary: str | None = None) -> FieldHandle:
        self._declare(name, FieldRole.INPUT, boundary)
        return FieldHandle(name, self.ndim, self)

    def inputs(self, *names: str):
        return tuple(self.input(n) for n in names)

    def output(self, name: str, boundary: str | None = None) -> FieldHandle:
        self._declare(name, FieldRole.OUTPUT, boundary)
        return FieldHandle(name, self.ndim, self)

    def outputs(self, *names: str):
        return tuple(self.output(n) for n in names)

    def temp(self, name: str, boundary: str | None = None) -> FieldHandle:
        """Field produced and consumed inside the program, never stored."""
        self._declare(name, FieldRole.TEMP, boundary)
        return FieldHandle(name, self.ndim, self)

    def scalar(self, name: str) -> ExprHandle:
        if name in self._scalars:
            raise ValueError(f"duplicate scalar {name!r}")
        self._scalars.append(name)
        return ExprHandle(ScalarRef(name))

    def scalars(self, *names: str):
        return tuple(self.scalar(n) for n in names)

    def coeff(self, name: str, axis: int) -> CoeffHandle:
        """Declare a 1-D coefficient array along ``axis`` ('small data')."""
        if name in self._coeffs:
            raise ValueError(f"duplicate coeff {name!r}")
        if not 0 <= axis < self.ndim:
            raise ValueError(f"axis {axis} out of range for {self.ndim}-D")
        self._coeffs[name] = axis
        return CoeffHandle(name, axis)

    def _declare(self, name: str, role: FieldRole, boundary: str | None = None):
        if name in self._fields:
            raise ValueError(f"duplicate field {name!r}")
        self._fields[name] = FieldDecl(name=name, role=role,
                                       boundary=boundary or self.boundary)

    # -- op definition ----------------------------------------------------
    def define(self, out: FieldHandle, expr, name: str = "") -> None:
        """stencil.apply: out = expr (one output field per op)."""
        if self._fields[out.name].role == FieldRole.INPUT:
            raise ValueError(f"cannot write input field {out.name!r}")
        if any(op.out == out.name for op in self._ops):
            raise ValueError(f"field {out.name!r} already defined")
        self._ops.append(StencilOp(out=out.name, expr=_wrap(expr),
                                   name=name or out.name))

    def build(self) -> Program:
        p = Program(name=self.name, ndim=self.ndim, fields=dict(self._fields),
                    scalars=list(self._scalars), ops=list(self._ops),
                    coeffs=dict(self._coeffs))
        p.validate()
        return p
