"""Streaming dataflow IR — the HLS-dialect analogue (paper §3.2).

The paper's middle layer sits between the ``stencil`` dialect and the
hardware: an explicit dataflow graph of streams and shift-register window
buffers in which *each input element is read from external memory exactly
once* and reused across the full stencil window (Fig. 2's 3/9/27-value
buffers).  This module is that layer for the TPU reproduction:

    stencil IR  --lower_to_dataflow-->  StreamGraph  --lower_stream-->  Pallas

A :class:`StreamGraph` holds one :class:`StreamRegion` per fuse group
(post-legalisation).  Each region is a small dataflow pipeline

    Load(field) -> Window(field, depth) -> Compute(op)* -> Store(field)

streamed plane-by-plane along the **outer** grid axis (axis 0; the
contiguous lane axis stays vectorised inside every plane):

* ``Window`` nodes are the shift registers: a rolling buffer of ``depth``
  planes per input field, where ``depth = lo-reach + region lead + 1`` is
  computed from the stencil access offsets.  One new plane enters per
  stream step; every reuse is a VMEM-resident slice.
* in-region producer->consumer dependencies along the stream axis become
  **ring buffers** over the producer's past planes (``Compute.ring``)
  instead of the block schedule's overlapped-tiling recompute — streamed
  dependencies are recompute-free by construction.
* margins along the *non-stream* axes still follow
  :func:`~repro.core.passes.infer_halo`-style propagation (the plane is
  evaluated slightly wide so consumers can shift within it).

Legalisation (:func:`legalize_stream_groups`) splits a fuse group wherever
streaming cannot honour a dependency in one sweep:

* a temp read at a **positive** stream offset would need a plane the
  pipeline has not produced yet (would require skewing) — split;
* a **periodic** temp read at a negative stream offset would need the end
  of the sweep at its beginning (wraparound is not yet resident) — split.

Split intermediates are materialised in HBM between regions, exactly like
the paper's inter-stage streams; external inputs never force a split (the
orchestrator pads them — zero slabs or torus wraparound — before the sweep).

**Temporal blocking** (``plan.time_tile = T > 1``, the paper's chained
timestep compute regions / the wafer-scale follow-up's pipelined time
steps): one sweep advances T time steps by chaining T copies of the
region's compute stage inside the kernel, with the fused-loop update rule
applied plane-wise between stages.  Chain stage ``s+1`` trails stage ``s``
by the region's stream lead, so halo margins and window-buffer depths
accumulate per chained step (:func:`chained_halo`), and each input plane is
fetched from HBM once per T steps.  The chain legalises like regions do —
:func:`chain_split_reason` demotes the *effective* tile (carried on
``StreamSpec.time_tile``) to 1 wherever one sweep cannot honour the chain:
multi-region programs (step intermediates materialise in HBM between
sweeps), periodic persistent fields (the updated field's wraparound planes
are not resident mid-sweep — the same rule that splits periodic temp
back-references), or regions that do not see every persistent field (the
update rule consumes them all).

**Spatial unrolling** (``plan.plane_tile = P > 1``, the paper's parallel
processing elements consuming multiple contiguous points per cycle): one
sweep grid step DMAs and computes P consecutive planes, shrinking the
sweep grid to ``ceil(n_steps / P)`` steps while keeping per-plane
semantics identical — the window shifts by P planes at a time and every
virtual step replays the single-plane pipeline.  Unlike the chain, plane
unrolling is legality-free by construction (rings, coefficients and
periodic wraparound all key off the *virtual* step), so the only demotion
(:func:`plane_split_reason`, effective value on ``StreamSpec.plane_tile``)
is geometric: P planes per step need at least P output planes in the
(shard-local) stream extent.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..obs.events import ChainDemoted, PlaneDemoted
from ..obs.trace import current_tracer
from .ir import FieldRole, Program
from .passes import GroupHalo, _zeros
from .schedule import StreamSpec

STREAM_AXIS = 0


# --------------------------------------------------------------------------
# Graph nodes (pure description — the lowering in lower_stream.py consumes
# the region geometry, the nodes document/validate the pipeline structure)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Load:
    """One plane of ``field`` enters the region from HBM per stream step."""

    field: str


@dataclasses.dataclass(frozen=True)
class Window:
    """Shift-register window buffer: ``depth`` resident planes of ``field``.

    ``lo`` planes of reach behind the output plane plus the region's lead
    ahead of it; each plane is loaded once and read ``depth`` times as it
    shifts through."""

    field: str
    depth: int
    lo: int
    hi: int


@dataclasses.dataclass(frozen=True)
class Compute:
    """Evaluate program op ``op`` at the output plane.

    ``ring > 0`` keeps that many planes of the result resident so in-region
    consumers can read past planes (stream-axis dependencies without
    recompute)."""

    op: int
    out: str
    ring: int = 0


@dataclasses.dataclass(frozen=True)
class Store:
    """One plane of ``field`` leaves the region to HBM per stream step."""

    field: str


@dataclasses.dataclass
class StreamRegion:
    """One streamed pipeline: a legalised fuse group plus its geometry."""

    ops: list                   # program op indices, in order
    nodes: list                 # Load/Window/Compute/Store pipeline
    halo: GroupHalo             # stream-aware margins + window halo
    depths: dict                # input field -> window buffer depth (planes)
    rings: dict                 # temp field -> ring buffer depth (planes)
    lead: int                   # stream-front lead over the output plane

    def describe(self) -> str:
        d = ",".join(f"{f}:{v}" for f, v in self.depths.items())
        return (f"region(ops={self.ops}, depths=[{d}], lead={self.lead})")


@dataclasses.dataclass
class StreamGraph:
    """The full dataflow program: ordered regions over one stream axis.

    ``time_tile`` is the *effective* temporal-blocking depth: the number of
    chained timestep stages one sweep advances (1 = no chaining, either
    because none was requested or because :func:`chain_split_reason` split
    the chain back to single steps).  ``plane_tile`` is the *effective*
    spatial-unrolling width: how many consecutive planes one sweep grid
    step advances (1 = plane-by-plane, either because none was requested
    or because :func:`plane_split_reason` demoted it)."""

    program: str
    axis: int
    regions: list
    time_tile: int = 1
    plane_tile: int = 1
    # the stream axis is domain-decomposed across a mesh: region halos were
    # built with :func:`stream_halo`'s sharded lo-propagation (ghost planes
    # must be *exact*, not maskable out-of-domain warm-up), and chain
    # accumulation deepens the lo side too (:func:`chained_halo`)
    stream_sharded: bool = False

    def spec(self) -> StreamSpec:
        """The plan-resident summary (what the tuner's cache round-trips)."""
        return StreamSpec(
            axis=self.axis,
            regions=tuple(tuple(r.ops) for r in self.regions),
            depths=tuple(dict(r.depths) for r in self.regions),
            rings=tuple(dict(r.rings) for r in self.regions),
            leads=tuple(r.lead for r in self.regions),
            time_tile=self.time_tile,
            plane_tile=self.plane_tile,
        )

    def group_halos(self) -> list:
        """One :class:`~repro.core.passes.GroupHalo` per *lowered kernel*:
        the region halos, chain-accumulated when this graph temporal-blocks
        (carry/shard sizing must cover what the chained kernels slice)."""
        return [chained_halo(r.halo, self.time_tile,
                             stream_sharded=self.stream_sharded)
                for r in self.regions]

    def to_text(self) -> str:
        """HLS-dialect-style dump (docs, debugging, golden tests)."""
        tt = f" time_tile={self.time_tile}" if self.time_tile > 1 else ""
        pt = f" plane_tile={self.plane_tile}" if self.plane_tile > 1 else ""
        lines = [f"dataflow.graph @{self.program} "
                 f"stream_axis={self.axis}{tt}{pt} {{"]
        for ri, r in enumerate(self.regions):
            lines.append(f"  dataflow.region @{ri} lead={r.lead} {{")
            for n in r.nodes:
                if isinstance(n, Load):
                    lines.append(f"    %{n.field} = dataflow.load")
                elif isinstance(n, Window):
                    lines.append(
                        f"    %{n.field}.win = dataflow.window(%{n.field}) "
                        f"depth={n.depth} reach=(-{n.lo},+{n.hi})")
                elif isinstance(n, Compute):
                    ring = f" ring={n.ring}" if n.ring else ""
                    lines.append(
                        f"    %{n.out} = dataflow.compute op#{n.op}{ring}")
                elif isinstance(n, Store):
                    lines.append(f"    dataflow.store %{n.field}")
            lines.append("  }")
        lines.append("}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Legalisation: which fuse groups can stream in one sweep?
# --------------------------------------------------------------------------


def stream_split_reason(p: Program, produced: set, op_index: int
                        ) -> str | None:
    """Why op ``op_index`` cannot join a region that produced ``produced``
    (None = it can)."""
    op = p.ops[op_index]
    for a in op.accesses():
        if a.field not in produced:
            continue
        o0 = int(a.offset[STREAM_AXIS])
        if o0 > 0:
            return (f"op {op.name or op.out!r} reads {a.field!r} at stream "
                    f"offset +{o0} (future plane)")
        if o0 < 0 and p.fields[a.field].boundary == "periodic":
            return (f"op {op.name or op.out!r} reads periodic temp "
                    f"{a.field!r} at stream offset {o0} (wraparound not "
                    "resident)")
    return None


def legalize_stream_groups(p: Program, groups: Sequence) -> list:
    """Split fuse groups so every region streams in a single forward sweep.

    Greedy in program order: an op that needs a future plane of an in-region
    temp (positive stream offset) or the wraparound of a periodic temp
    starts a new region; the temp then travels through HBM between regions,
    where the orchestrator can pad it like any other field."""
    out = []
    for grp in groups:
        cur: list = []
        produced: set = set()
        for i in grp:
            if cur and stream_split_reason(p, produced, i) is not None:
                out.append(cur)
                cur, produced = [], set()
            cur.append(i)
            produced.add(p.ops[i].out)
        if cur:
            out.append(cur)
    return out


# --------------------------------------------------------------------------
# Temporal-blocking (time_tile) chain legalisation
# --------------------------------------------------------------------------


def chain_split_reason(p: Program, regions: Sequence) -> str | None:
    """Why T > 1 timestep stages cannot chain through one sweep (None = they
    can).  The rules mirror region legalisation, applied at the step level:

    * **multiple regions** — step intermediates materialise in HBM between
      region sweeps, so the chain would break mid-step;
    * **periodic persistent field** — stage ``s+1`` reads the *updated*
      field, whose wraparound planes are produced in-sweep and are not
      resident (the periodic-temp back-reference rule, one level up);
    * **region inputs != persistent fields** — the update rule consumes
      every persistent field, so each chained stage must have all of them
      resident as planes.
    """
    if len(regions) != 1:
        return (f"program streams as {len(regions)} regions; chained steps "
                "would need inter-region intermediates resident mid-sweep")
    persistent = p.input_fields()
    for f in persistent:
        if p.fields[f].boundary == "periodic":
            return (f"persistent field {f!r} is periodic: the updated "
                    "field's wraparound planes are not resident mid-sweep")
    region = regions[0]
    inputs = {a.field for i in region for a in p.ops[i].accesses()
              if a.field not in {p.ops[j].out for j in region}}
    if not inputs <= set(persistent):
        return ("region reads non-persistent inputs "
                f"{sorted(inputs - set(persistent))}")
    if set(persistent) - inputs:
        # the update rule needs planes of every persistent field; fields
        # the stencil never reads have no window to chain through
        return ("persistent field(s) "
                f"{sorted(set(persistent) - inputs)} not read by the "
                "region; chained stages would lack their planes")
    return None


def effective_time_tile(p: Program, regions: Sequence, requested: int) -> int:
    """The chain depth one sweep can actually honour: the requested
    ``time_tile`` when :func:`chain_split_reason` allows it, else 1."""
    requested = max(1, int(requested))
    if requested == 1:
        return 1
    return 1 if chain_split_reason(p, regions) is not None else requested


def plane_split_reason(p: Program, plane_tile: int,
                       grid: Sequence[int] | None = None) -> str | None:
    """Why ``P > 1`` planes cannot advance per sweep grid step (None = they
    can).  Mirrors :func:`chain_split_reason`, one axis over: plane
    unrolling replays the single-plane pipeline per *virtual* step, so
    rings, coefficient reads and periodic wraparound are legal by
    construction and the only constraint is geometric — a P-plane step
    needs at least P output planes in the (shard-local) stream extent,
    otherwise the whole sweep degenerates to warm-up/remainder handling."""
    P = max(1, int(plane_tile))
    if P == 1:
        return None
    if grid is not None and P > int(grid[STREAM_AXIS]):
        return (f"plane_tile {P} exceeds the stream extent "
                f"{int(grid[STREAM_AXIS])}: a sweep step would span more "
                "planes than the (shard-local) domain holds")
    return None


def effective_plane_tile(p: Program, requested: int,
                         grid: Sequence[int] | None = None) -> int:
    """The plane-unroll width one sweep step can actually honour: the
    requested ``plane_tile`` when :func:`plane_split_reason` allows it,
    else 1.  With ``grid=None`` the geometric check is deferred (buffer
    depths do not depend on it); callers that know the grid re-derive."""
    requested = max(1, int(requested))
    if requested == 1:
        return 1
    return 1 if plane_split_reason(p, requested, grid) is not None \
        else requested


def chained_halo(gh: GroupHalo, time_tile: int,
                 stream_sharded: bool = False) -> GroupHalo:
    """Input-halo reach of a T-chained region (paper: margins accumulate
    per chained step).

    Stage ``s+1`` trails stage ``s`` by the region ``lead`` along the
    stream axis, so the sweep front runs ``T x lead`` planes ahead of the
    final output plane.  On the non-stream axes every chained stage widens
    the working extent by one full halo step, so external inputs must
    arrive padded by ``T x`` the single-step halo on both sides.

    The **lo side of the stream axis** depends on where the sweep starts:
    locally (``stream_sharded=False``) it stays one window deep — the
    warm-up planes below the sweep are out of the global domain, masked to
    zero, and the clamped output overwrites them — but when the stream axis
    is domain-decomposed the planes below a shard's block belong to its
    neighbour and every chained stage needs them *exact*, so the lo-side
    ghost planes deepen by one per-step reach per stage (``T x`` the
    sharded per-step lo halo).  ``margins`` are kept per-stage by the
    lowering; carry/shard sizing only consumes ``input_halo``."""
    T = max(1, int(time_tile))
    if T == 1:
        return gh
    halo = np.array(gh.input_halo)
    halo[0, 1] *= T              # stream front: lead accumulates per stage
    halo[1:, :] *= T             # non-stream: one halo step per stage
    if stream_sharded:
        halo[0, 0] *= T          # sharded sweep start: exact ghosts per stage
    return GroupHalo(margins=gh.margins, input_halo=halo,
                     group_inputs=gh.group_inputs,
                     group_outputs=gh.group_outputs,
                     internal=gh.internal, group_coeffs=gh.group_coeffs)


# --------------------------------------------------------------------------
# Stream-aware halo inference
# --------------------------------------------------------------------------


def stream_halo(p: Program, region: Sequence[int],
                stream_sharded: bool = False) -> GroupHalo:
    """Margins and window halo for one *stream* region.

    Differs from :func:`~repro.core.passes.infer_halo` exactly where the
    shift registers change the cost model: along the stream axis, producers
    get **no** evaluation margin (consumers read past planes out of the ring
    buffer instead of forcing recompute) and the window halo is the raw
    access reach (every op evaluates at the same output plane).  The
    non-stream axes keep the block schedule's margin propagation.

    With ``stream_sharded`` (the stream axis is domain-decomposed across a
    mesh) the lo-side stream halo additionally propagates through in-region
    producer chains: a ring-buffered temp read ``k`` planes back makes its
    producer's value load-bearing ``k`` planes below the output plane, and
    that producer's own external reads reach further still.  Locally this
    is unobservable — warm-up planes below the sweep are out of the global
    domain and masked to zero — but a shard whose block starts mid-domain
    must fetch *exact* neighbour planes deep enough that every ring warms
    up with true values before the first owned output plane.
    """
    region = list(region)
    gset = set(region)
    ndim = p.ndim
    producer = {p.ops[i].out: i for i in region}

    consumed_later = set()
    for j, op in enumerate(p.ops):
        if j in gset:
            continue
        for a in op.accesses():
            consumed_later.add(a.field)
    group_outputs, internal = [], []
    for i in region:
        out = p.ops[i].out
        if p.fields[out].role == FieldRole.OUTPUT or out in consumed_later:
            group_outputs.append(out)
        else:
            internal.append(out)

    margins = {i: _zeros(ndim) for i in region}
    # stream-axis lo margin per op: how many planes *below* the output
    # plane an op's value must be exact for in-region consumers (ring
    # back-references accumulate through producer chains).  Stays zero
    # unless the stream axis is sharded — locally the warm-up planes are
    # out-of-domain and masked, so no extra fetch is needed.
    smargin = {i: 0 for i in region}
    for i in reversed(region):
        m = margins[i]
        for a in p.ops[i].accesses():
            if a.field in producer and producer[a.field] in gset:
                pi = producer[a.field]
                if pi >= i:
                    raise ValueError("dependency violates program order")
                o0 = int(a.offset[STREAM_AXIS])
                if o0 > 0:
                    raise ValueError(
                        f"region {region} not stream-legal: {a.field!r} read "
                        f"at stream offset +{o0}; run legalize_stream_groups")
                if stream_sharded:
                    smargin[pi] = max(smargin[pi], smargin[i] - o0)
                need = _zeros(ndim)
                for ax in range(1, ndim):
                    o = a.offset[ax]
                    need[ax, 0] = max(0, m[ax, 0] - o)
                    need[ax, 1] = max(0, m[ax, 1] + o)
                margins[pi] = np.maximum(margins[pi], need)

    halo = _zeros(ndim)
    group_inputs: list = []
    group_coeffs: list = []
    for i in region:
        op = p.ops[i]
        m = margins[i]
        for a in op.accesses():
            if a.field in producer:
                continue
            if a.field not in group_inputs:
                group_inputs.append(a.field)
            o0 = int(a.offset[STREAM_AXIS])
            halo[0, 0] = max(halo[0, 0], smargin[i] - o0)
            halo[0, 1] = max(halo[0, 1], o0)
            for ax in range(1, ndim):
                o = a.offset[ax]
                halo[ax, 0] = max(halo[ax, 0], m[ax, 0] - o)
                halo[ax, 1] = max(halo[ax, 1], m[ax, 1] + o)
        for c in op.coeff_refs():
            ax = p.coeffs[c.coeff]
            if c.coeff not in group_coeffs:
                group_coeffs.append(c.coeff)
            if ax == STREAM_AXIS:
                halo[0, 0] = max(halo[0, 0], smargin[i] - c.offset)
                halo[0, 1] = max(halo[0, 1], c.offset)
            else:
                halo[ax, 0] = max(halo[ax, 0], m[ax, 0] - c.offset)
                halo[ax, 1] = max(halo[ax, 1], m[ax, 1] + c.offset)
    return GroupHalo(margins=margins, input_halo=halo,
                     group_inputs=group_inputs, group_outputs=group_outputs,
                     internal=internal, group_coeffs=group_coeffs)


# --------------------------------------------------------------------------
# Buffer sizing + graph construction
# --------------------------------------------------------------------------


def window_depths(p: Program, region: Sequence[int], gh: GroupHalo
                  ) -> tuple:
    """Per-field shift-register depths and temp ring depths for a region.

    An input field's window must hold every plane between its deepest
    back-reference and the stream front (which runs ``lead`` planes ahead
    of the output plane so the *widest* forward reach in the region is
    resident): ``depth = lo + lead + 1``.  A temp read at past planes keeps
    ``1 + max back-reference`` planes in its ring."""
    region = list(region)
    produced = {p.ops[i].out for i in region}
    lead = int(gh.input_halo[STREAM_AXIS, 1])
    lo_reach = {f: 0 for f in gh.group_inputs}
    ring_back: dict = {}
    for i in region:
        for a in p.ops[i].accesses():
            o0 = int(a.offset[STREAM_AXIS])
            if a.field in produced:
                if o0 < 0:
                    ring_back[a.field] = max(ring_back.get(a.field, 0), -o0)
            else:
                lo_reach[a.field] = max(lo_reach[a.field], -o0)
    depths = {f: lo_reach[f] + lead + 1 for f in gh.group_inputs}
    rings = {t: back + 1 for t, back in ring_back.items()}
    return depths, rings


def _regions_legal(p: Program, regions) -> bool:
    """Are these cached region splits still stream-legal for ``p``?  A
    cached :class:`~repro.core.schedule.StreamSpec` may come from a plan
    legalised against a program with different boundaries."""
    for region in regions:
        produced: set = set()
        for i in region:
            if produced and stream_split_reason(p, produced, i) is not None:
                return False
            produced.add(p.ops[i].out)
    return True


def lower_to_dataflow(p: Program, plan, grid: Sequence[int] | None = None,
                      stream_sharded: bool = False) -> StreamGraph:
    """Lower validated stencil IR + plan fuse groups to the dataflow layer.

    ``plan`` only contributes its ``groups`` (and, when present, a cached
    ``StreamSpec`` whose legalised regions are reused — after re-checking
    they are still legal for this program — so a plan deserialised from
    the tuner cache lowers identically).  ``grid`` is optional and only
    used for sanity checks — buffer depths derive from access offsets
    alone.

    ``stream_sharded`` marks the stream axis as domain-decomposed across a
    mesh: region input halos then carry the deepened lo-side ghost-plane
    reach (see :func:`stream_halo` / :func:`chained_halo`).  The legalised
    region split, window depths and ring depths are *identical* either way
    — a :class:`~repro.core.schedule.StreamSpec` cached from a local tune
    reuses cleanly under a mesh and vice versa.
    """
    if p.ndim < 2:
        raise ValueError(
            "schedule='stream' needs ndim >= 2: streaming the only axis "
            "would leave nothing vectorised inside a plane")
    spec = getattr(plan, "stream", None)
    if spec is not None and spec.regions \
            and _regions_legal(p, spec.regions):
        region_ops = [list(r) for r in spec.regions]
    else:
        # no cached geometry — or the cached split is illegal for *this*
        # program (e.g. the plan was legalised under zero boundaries and
        # is now compiled with ``boundary="periodic"``, where a temp's
        # negative stream offset may no longer ride a ring): re-legalise
        # from the fuse groups rather than silently mis-streaming
        region_ops = legalize_stream_groups(p, plan.groups)

    regions = []
    for ops in region_ops:
        gh = stream_halo(p, ops, stream_sharded=stream_sharded)
        depths, rings = window_depths(p, ops, gh)
        nodes: list = []
        for f in gh.group_inputs:
            nodes.append(Load(field=f))
            nodes.append(Window(field=f, depth=depths[f],
                                lo=depths[f] - 1 - int(gh.input_halo[0, 1]),
                                hi=int(gh.input_halo[0, 1])))
        for i in ops:
            nodes.append(Compute(op=i, out=p.ops[i].out,
                                 ring=rings.get(p.ops[i].out, 0)))
        for f in gh.group_outputs:
            nodes.append(Store(field=f))
        regions.append(StreamRegion(ops=list(ops), nodes=nodes, halo=gh,
                                    depths=depths, rings=rings,
                                    lead=int(gh.input_halo[0, 1])))

    if grid is not None:
        grid = tuple(int(g) for g in grid)
        if len(grid) != p.ndim:
            raise ValueError(f"grid rank {len(grid)} != ndim {p.ndim}")
    req_t = max(1, int(getattr(plan, "time_tile", 1)))
    req_p = max(1, int(getattr(plan, "plane_tile", 1)))
    eff = effective_time_tile(p, region_ops, req_t)
    eff_p = effective_plane_tile(p, req_p, grid)
    # demotions are *events*, not silent field values: the ambient tracer
    # (a no-op unless tracing is on) records why the request shrank, with
    # the same structured reason the compile-time warning carries
    tracer = current_tracer()
    if tracer.enabled:
        if eff < req_t:
            tracer.emit(ChainDemoted(
                program=p.name, requested=req_t, effective=eff,
                reason=chain_split_reason(p, region_ops) or ""))
        if eff_p < req_p:
            tracer.emit(PlaneDemoted(
                program=p.name, requested=req_p, effective=eff_p,
                reason=plane_split_reason(p, req_p, grid) or ""))
    return StreamGraph(program=p.name, axis=STREAM_AXIS, regions=regions,
                       time_tile=eff, plane_tile=eff_p,
                       stream_sharded=stream_sharded)
