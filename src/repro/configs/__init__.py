"""Assigned-architecture registry: ``get_config(arch)`` / ``get_smoke(arch)``.

Each ``<id>.py`` holds the exact published configuration (sources in the
module docstrings) plus a ``smoke()`` reduction of the same family used by
the CPU tests.
"""

from __future__ import annotations

import importlib

from .base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401

ARCHS = [
    "mixtral_8x7b",
    "grok_1_314b",
    "h2o_danube_1_8b",
    "nemotron_4_340b",
    "gemma2_2b",
    "gemma3_1b",
    "chameleon_34b",
    "hymba_1_5b",
    "whisper_small",
    "xlstm_350m",
]

def _canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def _module(arch: str):
    arch = _canon(arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()
