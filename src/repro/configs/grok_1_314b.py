"""Grok-1 314B [hf:xai-org/grok-1; unverified tier].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2, global attention with tanh logit softcap 30 (per released config).
Paper technique inapplicable to the attention (global); see DESIGN.md.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="decoder",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072,
        act="gelu", glu=True, norm="rmsnorm",
        pos="rope", rope_theta=10000.0,
        attn_softcap=30.0, final_softcap=30.0,
        n_experts=8, top_k=2,
        tie_embeddings=True, emb_scale=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=256, act="gelu", glu=True, attn_softcap=30.0,
        final_softcap=30.0, n_experts=4, top_k=2, emb_scale=True,
        max_seq=128,
    )
