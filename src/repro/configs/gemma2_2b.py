"""Gemma-2 2B [arXiv:2408.00118; hf:google/gemma-2-2b].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 — alternating
local(4096):global attention, attn logit softcap 50, final softcap 30,
sandwich (post) norms, GeGLU, head_dim 256, scaled embeddings.
Paper technique applies to the local layers.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="decoder",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
        d_ff=9216, vocab=256000,
        act="gelu_tanh", glu=True, norm="rmsnorm", post_norm=True,
        pos="rope", rope_theta=10000.0,
        window=4096, layer_pattern=("local", "global"),
        attn_softcap=50.0, final_softcap=30.0,
        tie_embeddings=True, emb_scale=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="decoder",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, act="gelu_tanh", glu=True, post_norm=True,
        window=16, layer_pattern=("local", "global"),
        attn_softcap=50.0, final_softcap=30.0, emb_scale=True, max_seq=128,
    )
