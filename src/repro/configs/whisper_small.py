"""Whisper small [arXiv:2212.04356; unverified tier].

Enc-dec, 12+12L d_model=768 12H d_ff=3072 vocab=51865, conv frontend STUB
(``input_specs()`` provides precomputed frame embeddings, enc_seq=1500),
learned positions, LayerNorm, GELU (non-gated).  ``max_seq`` is raised from
the published 448 to cover the assigned decode shapes (documented deviation).
The conv frontend is a literal 1-D stencil (see DESIGN.md).
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", modality="audio",
        n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865,
        act="gelu", glu=False, norm="layernorm",
        pos="learned", enc_seq=1500,
        tie_embeddings=True, max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec", modality="audio",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, act="gelu", glu=False, norm="layernorm",
        pos="learned", enc_seq=32, max_seq=128,
    )
