"""Model configuration schema for every assigned architecture.

One dataclass covers the whole pool: dense / MoE / hybrid(SSM+attn) / pure
recurrent / encoder-decoder.  Per-arch files under ``repro.configs``
instantiate the exact published configs plus a ``smoke()`` reduction of the
same family for CPU tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str = "decoder"          # decoder | encdec | hybrid | xlstm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0                  # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 32000
    act: str = "silu"                # silu | gelu | relu2 | gelu_tanh
    glu: bool = True                 # gated MLP (SwiGLU/GeGLU)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    post_norm: bool = False          # sandwich norm (gemma2)
    qk_norm: bool = False
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 10000.0
    max_seq: int = 131072

    # attention pattern
    window: int = 0                  # SWA width; 0 = global
    layer_pattern: Sequence[str] = ()  # e.g. ("local","global"); cycled.
    #                                  empty -> all local if window else global
    attn_softcap: float = 0.0        # tanh logit softcap (gemma2/grok)
    final_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0               # mamba d_state (hymba)
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0             # xlstm: every k-th layer is sLSTM

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500              # audio frames after conv stub
    modality: str = "text"           # text | audio | vlm

    # embeddings
    tie_embeddings: bool = True
    emb_scale: bool = False          # gemma: scale embeddings by sqrt(d)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 128    # pad embedding rows so TP divides vocab

    def __post_init__(self):
        if self.d_head == 0:
            self.d_head = self.d_model // self.n_heads
        if not self.layer_pattern:
            self.layer_pattern = ("local",) if self.window else ("global",)

    @property
    def vocab_padded(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab + m - 1) // m) * m

    # ------------------------------------------------------------------
    def layer_kind(self, i: int) -> str:
        """'local' (windowed) or 'global' attention for layer i."""
        return self.layer_pattern[i % len(self.layer_pattern)]

    def num_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        per_layer = 0
        # attention
        per_layer += d * h * dh + 2 * d * kv * dh + h * dh * d
        # mlp
        if self.n_experts:
            e = self.n_experts
            mlp = e * (d * f * (2 if self.glu else 1) + f * d)
            per_layer += mlp + d * e  # + router
        elif f > 0:
            per_layer += d * f * (2 if self.glu else 1) + f * d
        # norms
        per_layer += d * (4 if self.post_norm else 2)
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + di * (self.ssm_conv +
                                                     2 * self.ssm_state + 2)
        if self.family == "xlstm":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + 4 * di * dh  # gates etc. approx
        total = self.n_layers * per_layer
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        if self.family == "encdec":
            enc_layer = (d * h * dh + 2 * d * kv * dh + h * dh * d
                         + d * f * (2 if self.glu else 1) + f * d + 2 * d)
            total += self.n_enc_layers * enc_layer
            total += self.n_layers * (d * h * dh + 2 * d * kv * dh
                                      + h * dh * d + d)  # cross-attn
        return total

    def num_active_params(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if not self.n_experts:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        e, k = self.n_experts, self.top_k
        expert = d * f * (2 if self.glu else 1) + f * d
        inactive = self.n_layers * (e - k) * expert
        return self.num_params() - inactive

    def model_flops_per_token(self) -> float:
        """6·N_active (training: fwd+bwd) — the §Roofline MODEL_FLOPS basis."""
        return 6.0 * self.num_active_params()


@dataclasses.dataclass
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
