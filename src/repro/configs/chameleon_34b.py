"""Chameleon 34B [arXiv:2405.09818; unverified tier].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 — early-fusion
mixed-modal: VQ image tokens share the text vocabulary, so the backbone is
a standard dense decoder (qk-norm per the paper).  The VQ tokenizer is the
modality frontend STUB: ``input_specs()`` provides token ids drawn from the
joint vocab.  Paper technique inapplicable (global attention).
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="decoder", modality="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=65536,
        act="silu", glu=True, norm="rmsnorm", qk_norm=True,
        pos="rope", rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family="decoder", modality="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, act="silu", glu=True, qk_norm=True,
        tie_embeddings=False, max_seq=128,
    )
