"""Nemotron-4 340B [arXiv:2402.16819 family; unverified tier].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 — GQA,
squared-ReLU MLP (no gating), RoPE, untied embeddings.
Paper technique inapplicable (global attention); see DESIGN.md.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", family="decoder",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab=256000,
        act="relu2", glu=False, norm="layernorm",
        pos="rope", rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, act="relu2", glu=False, norm="layernorm",
        tie_embeddings=False, max_seq=128,
    )
