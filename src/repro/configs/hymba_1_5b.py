"""Hymba 1.5B [arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
hybrid-head: attention and Mamba heads run in PARALLEL in each block,
outputs summed.  Most layers use SWA (1024); a few are global (approximated
here as every 16th layer, the published model uses first/middle/last).
The paper technique applies twice: SWA windows + the streaming SSM state.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001,
        act="silu", glu=True, norm="rmsnorm",
        pos="rope", rope_theta=10000.0,
        window=1024,
        layer_pattern=("global",) + ("local",) * 15,
        ssm_state=16, ssm_conv=4, ssm_expand=2,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, act="silu", glu=True, window=16,
        layer_pattern=("global", "local"),
        ssm_state=8, ssm_conv=4, ssm_expand=2, max_seq=128,
    )
