"""Gemma-3 1B [hf:google/gemma-3-1b-pt; unverified tier].

26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global
pattern (window 512), qk-norm, sandwich norms, head_dim 256, 128k context.
Paper technique applies to the local layers (5/6 of the stack).
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="decoder",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_head=256,
        d_ff=6912, vocab=262144,
        act="gelu_tanh", glu=True, norm="rmsnorm", post_norm=True,
        qk_norm=True,
        pos="rope", rope_theta=1e6,
        window=512,
        layer_pattern=("local", "local", "local", "local", "local", "global"),
        tie_embeddings=True, emb_scale=True, max_seq=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="decoder",
        n_layers=6, d_model=48, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=96, vocab=256, act="gelu_tanh", glu=True, post_norm=True,
        qk_norm=True, window=8,
        layer_pattern=("local", "local", "local", "local", "local", "global"),
        emb_scale=True, max_seq=128,
    )
