"""Mixtral 8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts top-2,
sliding-window attention (4096).  All layers are SWA — the paper technique
(shift-buffer windows over the sequence dim) applies to every layer.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="decoder",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        act="silu", glu=True, norm="rmsnorm",
        pos="rope", rope_theta=1e6,
        window=4096, layer_pattern=("local",),
        n_experts=8, top_k=2,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, act="silu", glu=True, window=16,
        layer_pattern=("local",), n_experts=4, top_k=2,
        tie_embeddings=False, max_seq=128,
    )
