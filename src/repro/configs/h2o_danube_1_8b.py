"""H2O-Danube 1.8B [arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 — llama+mistral mix
with sliding-window attention; the paper technique applies (SWA windows).
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="decoder",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=6912, vocab=32000,
        act="silu", glu=True, norm="rmsnorm",
        pos="rope", rope_theta=10000.0,
        window=4096, layer_pattern=("local",),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, act="silu", glu=True, window=16,
        layer_pattern=("local",), max_seq=128,
    )
