"""xLSTM 350M [arXiv:2405.04517; unverified tier].

24L d_model=1024 4H d_ff=0 vocab=50304 — mLSTM blocks (matrix memory,
internal up-projection x2, no separate FFN) with sLSTM every 8th layer
(~7:1 ratio).  No positional encoding (the recurrence orders the sequence).
Fully recurrent: long_500k decode carries O(1) state — the paper's
shift-buffer/streaming structure is the architecture itself.
"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="xlstm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        norm="layernorm", pos="none", glu=False,
        ssm_expand=2, slstm_every=8,
        layer_pattern=("mlstm",) * 7 + ("slstm",),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab=256, norm="layernorm", pos="none", glu=False,
        ssm_expand=2, slstm_every=2, layer_pattern=("mlstm", "slstm"),
        max_seq=128,
    )
