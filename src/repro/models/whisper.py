"""Whisper-style encoder-decoder backbone.

Per the brief the audio frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings (B, enc_seq, d_model) — the conv1d x2 +
log-mel stack is represented by a single learned projection so the interface
matches (the real conv frontend is a 1-D stencil; see kernels/ and
DESIGN.md §Arch-applicability).

Encoder: bidirectional attention over frames (learned positions).
Decoder: causal self-attention + cross-attention, learned positions.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import shard_activation
from .layers import (AttnSpec, attention_apply, init_attention, init_mlp,
                     init_norm, mlp_apply, norm_apply)
from .transformer import cast_params

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _spec(cfg, causal):
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    d_head=cfg.d_head, causal=causal, window=0,
                    chunk=2048)


def _init_enc_block(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": init_attention(ks[0], cfg.d_model, _spec(cfg, False), dtype),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def _init_dec_block(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": init_attention(ks[0], cfg.d_model, _spec(cfg, True), dtype),
            "ln_x": init_norm(cfg.d_model, cfg.norm, dtype),
            "xattn": init_attention(ks[1], cfg.d_model, _spec(cfg, False), dtype),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def init_whisper(cfg: ModelConfig, key):
    dtype = _DT[cfg.param_dtype]
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(cfg.d_model)
    params = {
        "frontend_proj": (jax.random.normal(ks[0], (cfg.d_model, cfg.d_model))
                          * scale).astype(dtype),          # stub projection
        "enc_pos": (jax.random.normal(ks[1], (cfg.enc_seq, cfg.d_model))
                    * scale).astype(dtype),
        "embed": (jax.random.normal(ks[2], (cfg.vocab_padded, cfg.d_model))
                  * scale).astype(dtype),
        "dec_pos": (jax.random.normal(ks[3], (cfg.max_seq, cfg.d_model))
                    * scale).astype(dtype),
        "ln_enc": init_norm(cfg.d_model, cfg.norm, dtype),
        "ln_f": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    ek = jax.random.split(ks[4], cfg.n_enc_layers)
    enc = [_init_enc_block(cfg, k, dtype) for k in ek]
    params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
    dk = jax.random.split(ks[5], cfg.n_layers)
    dec = [_init_dec_block(cfg, k, dtype) for k in dk]
    params["dec_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
    return params


def encode(cfg, params, frames, remat=False):
    """frames: (B, enc_seq, d_model) precomputed embeddings (frontend stub)."""
    x = jnp.einsum("bsd,de->bse", frames.astype(_DT[cfg.dtype]),
                   params["frontend_proj"].astype(_DT[cfg.dtype]))
    x = x + params["enc_pos"][:x.shape[1]][None].astype(x.dtype)
    spec = _spec(cfg, False)

    def body(x, bp):
        bp = cast_params(bp, x.dtype)
        h = norm_apply(bp["ln1"], x, cfg.norm)
        x = x + attention_apply(bp["attn"], h, spec, use_rope=False,
                                norm_kind=cfg.norm)
        h = norm_apply(bp["ln2"], x, cfg.norm)
        x = x + mlp_apply(bp["mlp"], h, cfg.act)
        x = shard_activation(x, "residual")
        return x, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm_apply(cast_params(params["ln_enc"], x.dtype), x, cfg.norm)


def decode(cfg, params, enc_out, tokens, remat=False):
    B, S = tokens.shape
    x = params["embed"][tokens].astype(_DT[cfg.dtype])
    x = x + params["dec_pos"][:S][None].astype(x.dtype)
    self_spec = _spec(cfg, True)

    def body(x, bp):
        bp = cast_params(bp, x.dtype)
        h = norm_apply(bp["ln1"], x, cfg.norm)
        x = x + attention_apply(bp["attn"], h, self_spec, use_rope=False,
                                norm_kind=cfg.norm)
        h = norm_apply(bp["ln_x"], x, cfg.norm)
        k = jnp.einsum("bsd,dhk->bshk", enc_out, bp["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, bp["xattn"]["wv"])
        x = x + attention_apply(bp["xattn"], h, self_spec, use_rope=False,
                                kv_override=(k, v), norm_kind=cfg.norm)
        h = norm_apply(bp["ln2"], x, cfg.norm)
        x = x + mlp_apply(bp["mlp"], h, cfg.act)
        x = shard_activation(x, "residual")
        return x, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm_apply(cast_params(params["ln_f"], x.dtype), x, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"].astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(vid < cfg.vocab, logits, -1e30)
    return shard_activation(logits, "logits")


def whisper_forward(cfg, params, frames, tokens, remat=False):
    return decode(cfg, params, encode(cfg, params, frames, remat=remat),
                  tokens, remat=remat)


def whisper_loss(cfg, params, frames, tokens, labels, remat=False):
    logits = whisper_forward(cfg, params, frames, tokens, remat=remat)
    mask = labels >= 0
    lbl = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(jnp.where(vocab_iota == lbl[..., None], logits, 0.0),
                     axis=-1)
    ll = picked - logz
    denom = jnp.maximum(mask.sum(), 1)
    ce = -(ll * mask).sum() / denom
    return ce, {"ce": ce}


# --------------------------------------------------------------------------
# serving: prefill + cached decode
# --------------------------------------------------------------------------

def _wlayer(params, which, i):
    return jax.tree.map(lambda a: a[i], params[which])


def whisper_init_cache(cfg, batch, max_len):
    adt = _DT[cfg.dtype]
    cache = []
    for _ in range(cfg.n_layers):
        cache.append({
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), adt),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), adt),
            # cross-attention K/V are filled at prefill from the encoder
            "xk": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head), adt),
            "xv": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head), adt),
        })
    return cache


def whisper_prefill(cfg, params, frames, tokens, max_len):
    """Encode audio, run the decoder over the prompt, build caches."""
    from .layers import dense_attention
    B, S = tokens.shape
    enc_out = encode(cfg, params, frames)
    x = params["embed"][tokens].astype(_DT[cfg.dtype])
    x = x + params["dec_pos"][:S][None].astype(x.dtype)
    self_spec = _spec(cfg, True)
    cache = whisper_init_cache(cfg, B, max_len)
    for i in range(cfg.n_layers):
        bp = cast_params(_wlayer(params, "dec_blocks", i), x.dtype)
        entry = cache[i]
        h = norm_apply(bp["ln1"], x, cfg.norm)
        k = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wv"])
        entry["k"] = entry["k"].at[:, :S].set(k.astype(entry["k"].dtype))
        entry["v"] = entry["v"].at[:, :S].set(v.astype(entry["v"].dtype))
        x = x + attention_apply(bp["attn"], h, self_spec, use_rope=False,
                                norm_kind=cfg.norm)
        h = norm_apply(bp["ln_x"], x, cfg.norm)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out, bp["xattn"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_out, bp["xattn"]["wv"])
        entry["xk"] = xk.astype(entry["xk"].dtype)
        entry["xv"] = xv.astype(entry["xv"].dtype)
        x = x + attention_apply(bp["xattn"], h, self_spec, use_rope=False,
                                kv_override=(xk, xv), norm_kind=cfg.norm)
        h = norm_apply(bp["ln2"], x, cfg.norm)
        x = x + mlp_apply(bp["mlp"], h, cfg.act)
    x = norm_apply(cast_params(params["ln_f"], x.dtype), x, cfg.norm)
    logits = jnp.einsum("bd,vd->bv", x[:, -1],
                        params["embed"].astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def whisper_decode_step(cfg, params, cache, tokens, pos):
    """One decoder token with self-attn cache + fixed cross-attn KV."""
    from .layers import decode_attention, dense_attention, AttnSpec
    import dataclasses as _dc
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(_DT[cfg.dtype])
    x = x + jnp.take(params["dec_pos"], pos, axis=0).astype(x.dtype)[None]
    self_spec = _spec(cfg, True)
    new_cache = []
    for i in range(cfg.n_layers):
        bp = cast_params(_wlayer(params, "dec_blocks", i), x.dtype)
        entry = dict(cache[i])
        h = norm_apply(bp["ln1"], x[:, None], cfg.norm)[:, 0]
        attn, kc, vc = decode_attention(bp["attn"], h, entry["k"], entry["v"],
                                        pos, self_spec, use_rope=False,
                                        norm_kind=cfg.norm)
        entry["k"], entry["v"] = kc, vc
        x = x + attn
        h = norm_apply(bp["ln_x"], x[:, None], cfg.norm)
        q_spec = _dc.replace(self_spec, causal=False)
        out = dense_attention(
            jnp.einsum("bsd,dhk->bshk", h, bp["xattn"]["wq"]),
            entry["xk"], entry["xv"], q_spec)
        x = x + jnp.einsum("bshk,hkd->bsd", out, bp["xattn"]["wo"])[:, 0]
        h = norm_apply(bp["ln2"], x[:, None], cfg.norm)
        x = x + mlp_apply(bp["mlp"], h, cfg.act)[:, 0]
        new_cache.append(entry)
    x = norm_apply(cast_params(params["ln_f"], x.dtype), x[:, None], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(
        x.dtype)).astype(jnp.float32)
    return logits[:, 0], new_cache
