"""Unified LM covering the assigned decoder/hybrid/recurrent architectures.

Families:
  decoder  — (GQA | MQA) x (global | SWA | alternating local:global) x
             (dense | MoE) x (softcaps, qk-norm, squared-ReLU, GeGLU...)
  hybrid   — hymba: attention and a Mamba SSM head run in *parallel* in every
             block, outputs summed
  xlstm    — mLSTM blocks with sLSTM every k-th layer, no FFN (d_ff=0)

Two execution paths:
  * training / no-cache forward: ``lax.scan`` over stacked block params
    (uniform leaf shapes; heterogeneous layer kinds dispatched with
    ``lax.switch`` inside the scan) — fast compiles at 96 layers.
  * prefill / decode: python-unrolled layers with per-layer caches, so local
    (SWA) layers keep *ring-buffer* KV caches of length ``window`` — the
    sequence-dimension shift buffer — while global layers keep full caches.

Activation sharding hooks go through ``repro.dist.sharding.shard_activation``
(no-ops unless a mesh context is installed).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..dist.sharding import shard_activation
from . import ssm
from .layers import (AttnSpec, attention_apply, decode_attention,
                     init_attention, init_mlp, init_moe, init_norm,
                     mlp_apply, moe_apply, norm_apply)

_DT = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _attn_spec(cfg: ModelConfig, kind: str) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    d_head=cfg.d_head, causal=True,
                    window=cfg.window if kind == "local" else 0,
                    softcap=cfg.attn_softcap, qk_norm=cfg.qk_norm,
                    chunk=2048)


def _kind_ids(cfg: ModelConfig) -> jnp.ndarray:
    kinds = sorted(set(cfg.layer_pattern))
    table = {k: i for i, k in enumerate(kinds)}
    ids = [table[cfg.layer_kind(i)] for i in range(cfg.n_layers)]
    return jnp.asarray(ids, jnp.int32), kinds


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 8)
    p = {"ln1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if cfg.family == "xlstm":
        # superset params: every layer carries both cell kinds; the scan
        # dispatches on kind (sLSTM layers ignore mLSTM weights and v.v.)
        p["mlstm"] = ssm.init_mlstm(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.ssm_expand, dtype)
        if cfg.slstm_every:
            p["slstm"] = ssm.init_slstm(ks[1], cfg.d_model, cfg.n_heads, dtype)
        return p
    spec = _attn_spec(cfg, "global")
    p["attn"] = init_attention(ks[0], cfg.d_model, spec, dtype)
    p["ln2"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if cfg.post_norm:
        p["ln1_post"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["ln2_post"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if cfg.n_experts:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                            cfg.glu, dtype)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm.init_mamba(ks[2], cfg.d_model, cfg.ssm_state,
                                  cfg.ssm_expand, cfg.ssm_conv, dtype)
    return p


def init_lm(cfg: ModelConfig, key):
    dtype = _DT[cfg.param_dtype]
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(cfg.d_model)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model))
                  * scale).astype(dtype),
        "ln_f": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_padded)) * scale).astype(dtype)
    if cfg.pos == "learned":
        params["pos_emb"] = (jax.random.normal(ks[2], (cfg.max_seq,
                                                       cfg.d_model))
                             * scale).astype(dtype)
    bkeys = jax.random.split(ks[3], cfg.n_layers)
    blocks = [init_block(cfg, bk, dtype) for bk in bkeys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return params


# --------------------------------------------------------------------------
# block application (shared by scan + unrolled paths)
# --------------------------------------------------------------------------

def cast_params(p, dtype):
    """Mixed precision: compute in ``dtype``, master params stay f32."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, p)


def block_apply(cfg: ModelConfig, bp, x, kind: str, positions):
    bp = cast_params(bp, _DT[cfg.dtype])
    h = norm_apply(bp["ln1"], x, cfg.norm)
    if cfg.family == "xlstm":
        if kind == "slstm":
            y, _ = ssm.slstm_apply(bp["slstm"], h)
        else:
            y, _ = ssm.mlstm_apply(bp["mlstm"], h)
        return x + y, jnp.float32(0.0)
    spec = _attn_spec(cfg, kind)
    attn = attention_apply(bp["attn"], h, spec, positions, cfg.rope_theta,
                           use_rope=(cfg.pos == "rope"), norm_kind=cfg.norm)
    if cfg.family == "hybrid":
        smo, _ = ssm.mamba_apply(bp["ssm"], h)
        attn = attn + smo
    if cfg.post_norm:
        attn = norm_apply(bp["ln1_post"], attn, cfg.norm)
    x = x + attn
    x = shard_activation(x, "residual")
    h = norm_apply(bp["ln2"], x, cfg.norm)
    aux = jnp.float32(0.0)
    if cfg.n_experts:
        y, aux = moe_apply(bp["moe"], h, cfg.top_k, cfg.act,
                           cfg.capacity_factor)
    elif cfg.d_ff:
        y = mlp_apply(bp["mlp"], h, cfg.act)
    else:
        y = jnp.zeros_like(h)
    if cfg.post_norm:
        y = norm_apply(bp["ln2_post"], y, cfg.norm)
    return x + y, aux


# --------------------------------------------------------------------------
# forward (training / scoring)
# --------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.pos == "learned":
        S = tokens.shape[1]
        x = x + params["pos_emb"][:S][None]
    return x.astype(_DT[cfg.dtype])


def unembed(cfg, params, x):
    x = norm_apply(cast_params(params["ln_f"], _DT[cfg.dtype]), x, cfg.norm)
    table = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:
        # mask padding rows so softmax/argmax ignore them (stays sharded)
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(vid < cfg.vocab, logits, -1e30)
    return shard_activation(logits, "logits")


def lm_forward(cfg: ModelConfig, params, tokens, remat: bool = False):
    """tokens (B,S) int32 -> logits (B,S,V) f32.  Scan over stacked blocks."""
    x = embed_tokens(cfg, params, tokens)
    x = shard_activation(x, "residual")
    positions = jnp.arange(tokens.shape[1])
    kind_ids, kinds = _kind_ids(cfg)

    def body(x, inp):
        bp, kid = inp
        if len(kinds) == 1:
            out, aux = block_apply(cfg, bp, x, kinds[0], positions)
        else:
            out, aux = jax.lax.switch(
                kid, [functools.partial(block_apply, cfg, bp, kind=k,
                                        positions=positions) for k in kinds],
                x)
        return out, aux

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = jax.lax.scan(body, x, (params["blocks"], kind_ids))
    return unembed(cfg, params, x), auxs.mean()


def lm_loss(cfg: ModelConfig, params, tokens, labels, remat=False,
            aux_weight=0.01, z_weight=1e-4):
    """Next-token CE (labels = tokens shifted by caller); -100 masks."""
    logits, aux = lm_forward(cfg, params, tokens, remat=remat)
    mask = labels >= 0
    lbl = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # label logit via mask-sum (not take_along_axis): the compare/select/
    # reduce fuses and stays vocab-sharded — no logits all-gather under TP
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(jnp.where(vocab_iota == lbl[..., None], logits, 0.0),
                     axis=-1)
    ll = picked - logz
    denom = jnp.maximum(mask.sum(), 1)
    ce = -(ll * mask).sum() / denom
    z_loss = ((logz * mask) ** 2).sum() / denom
    loss = ce + aux_weight * aux + z_weight * z_loss
    return loss, {"ce": ce, "aux": aux, "z": z_loss,
                  "ppl": jnp.exp(jnp.minimum(ce, 20.0))}


# --------------------------------------------------------------------------
# KV / state caches (prefill + decode)
# --------------------------------------------------------------------------

def _layer_params(params, i):
    if "layers" in params:      # unstacked (serving layout): free access
        return params["layers"][i]
    return jax.tree.map(lambda a: a[i], params["blocks"])


def unstack_params(cfg, params):
    """Serving layout: per-layer param trees instead of the scan stack.

    Dynamic-slicing the (L, ...) stack inside a decode step materialises a
    full copy of the weights as temporaries; serving engines store weights
    unstacked so layer access is free."""
    out = {k: v for k, v in params.items() if k != "blocks"}
    out["layers"] = [jax.tree.map(lambda a: a[i], params["blocks"])
                     for i in range(cfg.n_layers)]
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer cache list.  Local (SWA) layers get ring buffers of length
    ``window`` — bounded state for arbitrarily long decodes."""
    adt = _DT[cfg.dtype]
    cache = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        entry = {}
        if cfg.family == "xlstm":
            if kind == "slstm":
                entry = {"state": (jnp.zeros((batch, cfg.d_model),
                                             jnp.float32),) * 4}
            else:
                di = cfg.ssm_expand * cfg.d_model
                dh = di // cfg.n_heads
                entry = {"state": ssm.mlstm_init_state_b(batch, cfg.n_heads, dh)}
            cache.append(entry)
            continue
        L = min(cfg.window, max_len) if (kind == "local" and cfg.window) \
            else max_len
        entry = {"k": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.d_head), adt),
                 "v": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.d_head), adt)}
        if cfg.family == "hybrid":
            di = cfg.ssm_expand * cfg.d_model
            entry["ssm"] = (jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
                            jnp.zeros((batch, cfg.ssm_conv - 1, di), adt))
        cache.append(entry)
    return cache


def _is_ring(cfg: ModelConfig, kind: str) -> bool:
    return kind == "local" and cfg.window > 0


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    """Process a prompt (B,S); return (last-position logits, filled cache)."""
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(S)
    cache = init_cache(cfg, B, max_len)
    new_cache = []
    for i in range(cfg.n_layers):
        bp = cast_params(_layer_params(params, i), _DT[cfg.dtype])
        kind = cfg.layer_kind(i)
        entry = dict(cache[i])
        if cfg.family == "xlstm":
            h = norm_apply(bp["ln1"], x, cfg.norm)
            if kind == "slstm":
                y, st = ssm.slstm_apply(bp["slstm"], h)
            else:
                y, st = ssm.mlstm_apply(bp["mlstm"], h)
            entry["state"] = st
            x = x + y
            new_cache.append(entry)
            continue
        h = norm_apply(bp["ln1"], x, cfg.norm)
        spec = _attn_spec(cfg, kind)
        # compute attention over the prompt and capture k/v for the cache
        k = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wv"])
        attn = attention_apply(bp["attn"], h, spec, positions, cfg.rope_theta,
                               use_rope=(cfg.pos == "rope"),
                               norm_kind=cfg.norm)
        if spec.qk_norm:
            k = norm_apply(bp["attn"]["k_norm"], k, cfg.norm)
        if cfg.pos == "rope":
            k = ssm_apply_rope_guard(k, positions, cfg.rope_theta)
        kc, vc = entry["k"], entry["v"]
        L = kc.shape[1]
        ring = _is_ring(cfg, kind)
        if ring and S >= L:
            # ring buffer smaller than the prompt: keep the last L KVs at
            # their rotated slots (slot = position % L)
            idx = jnp.arange(S - L, S) % L
            kc = kc.at[:, idx].set(k[:, -L:].astype(kc.dtype))
            vc = vc.at[:, idx].set(v[:, -L:].astype(vc.dtype))
        else:
            kc = kc.at[:, :S].set(k.astype(kc.dtype))
            vc = vc.at[:, :S].set(v.astype(vc.dtype))
        entry["k"], entry["v"] = kc, vc
        if cfg.family == "hybrid":
            smo, st = ssm.mamba_apply(bp["ssm"], h)
            entry["ssm"] = st
            attn = attn + smo
        if cfg.post_norm:
            attn = norm_apply(bp["ln1_post"], attn, cfg.norm)
        x = x + attn
        h2 = norm_apply(bp["ln2"], x, cfg.norm)
        if cfg.n_experts:
            y, _ = moe_apply(bp["moe"], h2, cfg.top_k, cfg.act,
                             cfg.capacity_factor)
        elif cfg.d_ff:
            y = mlp_apply(bp["mlp"], h2, cfg.act)
        else:
            y = jnp.zeros_like(h2)
        if cfg.post_norm:
            y = norm_apply(bp["ln2_post"], y, cfg.norm)
        x = x + y
        new_cache.append(entry)
    logits = unembed(cfg, params, x[:, -1:])
    return logits[:, 0], new_cache


def ssm_apply_rope_guard(k, positions, theta):
    from .layers import apply_rope
    return apply_rope(k, positions, theta)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step: tokens (B,) int32, pos scalar -> (logits, cache)."""
    x = embed_tokens(cfg, params, tokens[:, None])[:, 0]      # (B,D)
    new_cache = []
    for i in range(cfg.n_layers):
        bp = cast_params(_layer_params(params, i), _DT[cfg.dtype])
        kind = cfg.layer_kind(i)
        entry = dict(cache[i])
        if cfg.family == "xlstm":
            h = norm_apply(bp["ln1"], x[:, None], cfg.norm)
            if kind == "slstm":
                y, st = ssm.slstm_apply(bp["slstm"], h, entry["state"])
            else:
                y, st = ssm.mlstm_apply(bp["mlstm"], h, entry["state"])
            entry["state"] = st
            x = x + y[:, 0]
            new_cache.append(entry)
            continue
        h = norm_apply(bp["ln1"], x[:, None], cfg.norm)[:, 0]
        spec = _attn_spec(cfg, kind)
        attn, kc, vc = decode_attention(
            bp["attn"], h, entry["k"], entry["v"], pos, spec, cfg.rope_theta,
            use_rope=(cfg.pos == "rope"), ring=_is_ring(cfg, kind),
            norm_kind=cfg.norm)
        entry["k"], entry["v"] = kc, vc
        if cfg.family == "hybrid":
            smo, st = ssm.mamba_apply(bp["ssm"], h[:, None], entry["ssm"])
            entry["ssm"] = st
            attn = attn + smo[:, 0]
        if cfg.post_norm:
            attn = norm_apply(bp["ln1_post"], attn, cfg.norm)
        x = x + attn
        h2 = norm_apply(bp["ln2"], x[:, None], cfg.norm)
        if cfg.n_experts:
            y, _ = moe_apply(bp["moe"], h2, cfg.top_k, cfg.act,
                             cfg.capacity_factor, no_drop=True)
            y = y[:, 0]
        elif cfg.d_ff:
            y = mlp_apply(bp["mlp"], h2, cfg.act)[:, 0]
        else:
            y = jnp.zeros_like(x)
        if cfg.post_norm:
            y = norm_apply(bp["ln2_post"], y, cfg.norm)
        x = x + y
        new_cache.append(entry)
    logits = unembed(cfg, params, x[:, None])
    return logits[:, 0], new_cache
