from .transformer import (init_lm, lm_forward, lm_loss, init_cache,
                          prefill, decode_step)
from .whisper import init_whisper, whisper_forward, whisper_loss
from .lm_serve import LMServeStats, ServeEngine, sample_token
