"""Transformer building blocks: norms, RoPE, attention family, MLP, MoE.

Pure-functional JAX (no framework): ``init_*`` return param pytrees (nested
dicts of arrays), ``*_apply`` are shape-polymorphic functions.  Everything is
batch-first ``(B, S, ...)`` and scan-friendly (uniform per-layer shapes).

Attention paths:
* dense masked attention for short sequences (training shapes)
* blockwise flash (lax.scan over KV chunks, running max/denominator) for
  long prefill — O(S·chunk) memory
* sliding-window attention via per-q-block KV slabs — the paper's
  shift-buffer idea applied to the sequence dimension: each query tile reads
  a bounded overlapping window, O(S·(w+Bq)) compute (see kernels/swa.py for
  the Pallas twin)
* decode attention over a (possibly ring-buffer) KV cache
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(d, kind="rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (nrm * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"] + p["bias"]).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(d_head, theta):
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions, theta=10000.0):
    """x: (B, S, H, Dh); positions: (B, S) or (S,) absolute positions."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                 # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    d_head: int
    causal: bool = True
    window: int = 0            # 0 = global
    softcap: float = 0.0
    chunk: int = 1024          # blockwise path threshold/size
    qk_norm: bool = False


def init_attention(key, d_model, spec: AttnSpec, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    h, kv, dh = spec.n_heads, spec.n_kv_heads, spec.d_head
    p = {
        "wq": _dense_init(ks[0], (d_model, h, dh), d_model, dtype),
        "wk": _dense_init(ks[1], (d_model, kv, dh), d_model, dtype),
        "wv": _dense_init(ks[2], (d_model, kv, dh), d_model, dtype),
        "wo": _dense_init(ks[3], (h, dh, d_model), h * dh, dtype),
    }
    if spec.qk_norm:
        p["q_norm"] = init_norm(dh, dtype=dtype)
        p["k_norm"] = init_norm(dh, dtype=dtype)
    return p


def _repeat_kv(k, n_heads):
    kvh = k.shape[-2]
    if kvh == n_heads:
        return k
    return jnp.repeat(k, n_heads // kvh, axis=-2)


def _softcap(logits, cap):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _dense_scores(q, k, spec, qpos, kpos):
    """(B,Sq,H,D)x(B,Sk,H,D) -> masked f32 logits (B,H,Sq,Sk)."""
    scale = 1.0 / math.sqrt(spec.d_head)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, spec.softcap)
    mask = jnp.ones((1, 1), jnp.bool_)
    dq, dk = qpos[:, None], kpos[None, :]
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if spec.causal:
        ok &= dk <= dq
    if spec.window:
        ok &= dk > dq - spec.window
    return jnp.where(ok[None, None], logits, -1e30)


def dense_attention(q, k, v, spec: AttnSpec, qpos=None, kpos=None):
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    if qpos is None:
        qpos = jnp.arange(Sq)
    if kpos is None:
        kpos = jnp.arange(Sk)
    k = _repeat_kv(k, spec.n_heads)
    v = _repeat_kv(v, spec.n_heads)
    logits = _dense_scores(q, k, spec, qpos, kpos)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def flash_attention(q, k, v, spec: AttnSpec):
    """Blockwise attention, O(S·chunk) memory: lax.scan over KV chunks."""
    B, S, H, D = q.shape
    C = min(spec.chunk, S)
    if S % C:
        raise ValueError(f"seq {S} not divisible by chunk {C}")
    k = _repeat_kv(k, spec.n_heads)
    v = _repeat_kv(v, spec.n_heads)
    nkv = S // C
    kc = k.reshape(B, nkv, C, H, D)
    vc = v.reshape(B, nkv, C, H, D)
    scale = 1.0 / math.sqrt(D)
    qpos = jnp.arange(S)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, blk = inputs
        kpos = blk * C + jnp.arange(C)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                            preferred_element_type=jnp.float32) * scale
        logits = _softcap(logits, spec.softcap)
        ok = jnp.ones((S, C), jnp.bool_)
        if spec.causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if spec.window:
            ok &= kpos[None, :] > qpos[:, None] - spec.window
        logits = jnp.where(ok[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nkv)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # (B,S,H,D)


def swa_attention(q, k, v, spec: AttnSpec):
    """Sliding-window attention via per-q-block KV slabs (stencil pattern).

    Query tile i attends to KV positions [i·Bq − w, (i+1)·Bq): an overlapping
    window slab — the exact structure of the stencil shift buffer, with halo
    = window.  O(S·(w + Bq)) compute and memory.
    """
    B, S, H, D = q.shape
    w = spec.window
    Bq = min(max(spec.chunk // 2, 128), S)
    if S % Bq:
        raise ValueError(f"seq {S} not divisible by q-block {Bq}")
    nb = S // Bq
    k = _repeat_kv(k, spec.n_heads)
    v = _repeat_kv(v, spec.n_heads)
    slab = w + Bq
    # pad KV on the left by w so every slab is in range
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(D)

    def block(i):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * Bq, Bq, axis=1)
        k_blk = jax.lax.dynamic_slice_in_dim(kp, i * Bq, slab, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, i * Bq, slab, axis=1)
        qpos = i * Bq + jnp.arange(Bq)
        kpos = i * Bq - w + jnp.arange(slab)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                            preferred_element_type=jnp.float32) * scale
        logits = _softcap(logits, spec.softcap)
        ok = (kpos[None, :] <= qpos[:, None]) & \
             (kpos[None, :] > qpos[:, None] - w) & (kpos[None, :] >= 0)
        logits = jnp.where(ok[None, None], logits, -1e30)
        wgt = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", wgt, v_blk)

    out = jax.lax.map(block, jnp.arange(nb))        # (nb,B,Bq,H,D)
    return out.swapaxes(0, 1).reshape(B, S, H, D)


def attention_apply(p, x, spec: AttnSpec, positions=None, rope_theta=10000.0,
                    use_rope=True, kv_override=None, norm_kind="rmsnorm"):
    """Full attention block: proj -> rope -> attend -> out-proj.

    ``kv_override``: (k, v) from an encoder for cross-attention.
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        k, v = kv_override
    if spec.qk_norm:
        q = norm_apply(p["q_norm"], q, norm_kind)
        k = norm_apply(p["k_norm"], k, norm_kind)
    if positions is None:
        positions = jnp.arange(S)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, rope_theta)
    if kv_override is not None:
        out = dense_attention(q, k, v, dataclasses.replace(spec, causal=False,
                                                           window=0))
    elif spec.window and S > spec.window:
        out = swa_attention(q, k, v, spec)
    elif S > spec.chunk:
        out = flash_attention(q, k, v, spec)
    else:
        out = dense_attention(q, k, v, spec)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# -------------------------------------------------------------------- decode

def decode_attention(p, x, cache_k, cache_v, pos, spec: AttnSpec,
                     rope_theta=10000.0, use_rope=True, ring=False,
                     norm_kind="rmsnorm"):
    """One-token attention against a KV cache.

    ``ring=True`` (SWA layers): the cache is a ring buffer of length
    ``window`` — the sequence-dimension shift buffer; new KV overwrite slot
    ``pos % window``.
    Returns (attn_out, new_k_cache, new_v_cache).
    """
    B = x.shape[0]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])[:, None]      # (B,1,H,D)
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])[:, None]
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])[:, None]
    if spec.qk_norm:
        q = norm_apply(p["q_norm"], q, norm_kind)
        k = norm_apply(p["k_norm"], k, norm_kind)
    posv = jnp.full((B, 1), pos)
    if use_rope:
        q = apply_rope(q, posv, rope_theta)
        k = apply_rope(k, posv, rope_theta)
    L = cache_k.shape[1]
    slot = (pos % L) if ring else jnp.minimum(pos, L - 1)
    # one-hot select write instead of dynamic-update-slice: elementwise ops
    # partition trivially, so the cache can stay sharded along the LENGTH
    # dim (flash-decoding layout) — a DUS on a sharded dim would force
    # GSPMD to all-gather the whole cache every token.
    sel = (jnp.arange(L) == slot)[None, :, None, None]
    ck = jnp.where(sel, k.astype(cache_k.dtype), cache_k)
    cv = jnp.where(sel, v.astype(cache_v.dtype), cache_v)
    from ..dist.sharding import shard_activation
    ck = shard_activation(ck, "cache")
    cv = shard_activation(cv, "cache")
    # grouped-query formulation: never materialise repeated KV — the
    # broadcast+reshape of jnp.repeat does not propagate a length-sharded
    # layout through GSPMD (it forced full cache all-gathers)
    KV = ck.shape[2]
    G = spec.n_heads // KV
    qg = q[:, 0].reshape(q.shape[0], KV, G, spec.d_head)       # (B,KV,G,D)
    scale = 1.0 / math.sqrt(spec.d_head)
    logits = jnp.einsum("bkgd,blkd->bkgl", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, spec.softcap)
    idx = jnp.arange(L)
    if ring:
        valid = idx <= pos                 # until buffer full; then all valid
        valid = jnp.where(pos >= L, jnp.ones_like(valid), valid)
    else:
        valid = idx <= pos
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", w,
                     cv.astype(jnp.float32))                    # (B,KV,G,D)
    out = out.reshape(q.shape[0], spec.n_heads, spec.d_head).astype(q.dtype)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), ck, cv


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=False),
    "gelu_tanh": functools.partial(jax.nn.gelu, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def init_mlp(key, d_model, d_ff, glu=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": _dense_init(ks[0], (d_model, d_ff), d_model, dtype),
         "w_out": _dense_init(ks[1], (d_ff, d_model), d_ff, dtype)}
    if glu:
        p["w_gate"] = _dense_init(ks[2], (d_model, d_ff), d_model, dtype)
    return p


def mlp_apply(p, x, act="silu"):
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


def init_moe(key, d_model, d_ff, n_experts, glu=True, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {"router": _dense_init(ks[0], (d_model, n_experts), d_model,
                               jnp.float32),
         "w_in": _dense_init(ks[1], (n_experts, d_model, d_ff), d_model, dtype),
         "w_out": _dense_init(ks[2], (n_experts, d_ff, d_model), d_ff, dtype)}
    if glu:
        p["w_gate"] = _dense_init(ks[3], (n_experts, d_model, d_ff), d_model,
                                  dtype)
    return p


def moe_apply(p, x, top_k=2, act="silu", capacity_factor=1.25,
              no_drop=False):
    """Capacity-factor scatter dispatch (GShard-style), expert-TP friendly.

    x: (B, S, D) -> (B, S, D).  Tokens above an expert's capacity are dropped
    (contribute zero) — the standard trade for static shapes on TPU.
    ``no_drop=True`` sizes capacity at the worst case (decode path: exact).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    T = B * S
    xf = x.reshape(T, D)
    gate_logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)                  # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    if no_drop and T * top_k <= E:
        # decode fast path (tiny T): gather ONLY the selected experts'
        # weights — HBM reads drop from all-E to top-k per token, the
        # difference between dense-dispatch and the 6·N_active roofline
        w_in_sel = p["w_in"][top_i]                             # (T,k,D,F)
        h = jnp.einsum("td,tkdf->tkf", xf, w_in_sel)
        if "w_gate" in p:
            g = jnp.einsum("td,tkdf->tkf", xf, p["w_gate"][top_i])
            h = _ACTS[act](g) * h
        else:
            h = _ACTS[act](h)
        out = jnp.einsum("tkf,tkfd->tkd", h, p["w_out"][top_i])
        y = (out * top_p[..., None].astype(x.dtype)).sum(axis=1)
        aux = _load_balance_loss(probs, top_i, E)
        return y.reshape(B, S, D), aux

    eid = top_i.reshape(-1)                                     # (T*k,)
    wgt = top_p.reshape(-1)
    tid = jnp.repeat(jnp.arange(T), top_k)
    cap = T if no_drop else max(int(capacity_factor * T * top_k / E), 1)

    oh = jax.nn.one_hot(eid, E, dtype=jnp.int32)                # (T*k, E)
    rank = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                               eid[:, None], axis=1)[:, 0]      # (T*k,)
    keep = rank < cap
    rank = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E, cap, D), x.dtype)
    buf = buf.at[eid, rank].add(
        jnp.where(keep[:, None], xf[tid], jnp.zeros_like(xf[tid])))

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"])           # (E,cap,D)

    gathered = out_e[eid, rank]                                 # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, jnp.zeros_like(gathered))
    y = jnp.zeros((T, D), x.dtype).at[tid].add(
        gathered * wgt[:, None].astype(x.dtype))
    aux = _load_balance_loss(probs, top_i, E)
    return y.reshape(B, S, D), aux


def _load_balance_loss(probs, top_i, E):
    """Switch-style auxiliary load-balancing loss."""
    T = probs.shape[0]
    fraction = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), 0)
    prob_mass = jnp.mean(probs, axis=0)
    return E * jnp.sum(fraction * prob_mass)
