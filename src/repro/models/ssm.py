"""State-space / recurrent blocks: Mamba-style selective SSM (hymba) and
xLSTM's mLSTM / sLSTM.

Streaming structure: all three are linear-in-sequence recurrences — the
sequence-dimension analogue of the paper's shift buffer (bounded state
carried forward, one element in / one result out per step).

Training/prefill uses *chunkwise* parallel forms: ``lax.scan`` over sequence
chunks carrying the recurrent state, parallel math inside the chunk — the
same carried-state + tile pattern as the stencil backend, keeping memory
O(S·chunk) instead of O(S²) (mLSTM) / O(S·d·N) (associative scan).
Decode uses O(1) state updates.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import _dense_init, init_norm, norm_apply

_CHUNK = 256


def _split_chunks(x, c):
    B, S = x.shape[:2]
    return x.reshape(B, S // c, c, *x.shape[2:]).swapaxes(0, 1)  # (nc,B,c,...)


def _merge_chunks(x):
    nc, B, c = x.shape[:3]
    return x.swapaxes(0, 1).reshape(B, nc * c, *x.shape[3:])


# --------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's parallel-head partner to attention)
# --------------------------------------------------------------------------

def init_mamba(key, d_model, d_state=16, expand=2, conv=4, dtype=jnp.float32):
    di = expand * d_model
    ks = jax.random.split(key, 7)
    return {
        "w_in": _dense_init(ks[0], (d_model, 2 * di), d_model, dtype),
        "conv_w": _dense_init(ks[1], (conv, di), conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bc": _dense_init(ks[2], (di, 2 * d_state), di, dtype),
        "w_dt": _dense_init(ks[3], (di, 1), di, dtype),
        "dt_bias": jnp.full((di,), -4.0, dtype),     # softplus -> small dt
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1,
                                             dtype=jnp.float32), (di, 1))),
        "D_skip": jnp.ones((di,), dtype),
        "w_out": _dense_init(ks[4], (di, d_model), di, dtype),
    }


def _causal_conv1d(x, w, b):
    """x: (B,S,C), depthwise causal conv, kernel (K,C) — a 1-D stencil."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K tiny (4); unrolled shifted adds
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b


def mamba_apply(p, x, state=None, chunk=_CHUNK):
    """x: (B,S,D) -> (y, new_state).

    state None  -> chunkwise scan over S (training/prefill)
    state given -> single-step decode (S == 1); state = (h, conv_tail)
    """
    B, S, D = x.shape
    K = p["conv_w"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        xi_raw = xi
        xi = _causal_conv1d(xi_raw, p["conv_w"], p["conv_b"])
        conv_tail = xi_raw[:, -(K - 1):]   # raw (pre-conv) tail for decode
    else:
        h_prev, tail = state
        seq = jnp.concatenate([tail, xi], axis=1)
        xi = (seq[:, -K:] * p["conv_w"]).sum(1, keepdims=True) + p["conv_b"]
        conv_tail = seq[:, -(K - 1):]
    xi = jax.nn.silu(xi)

    bc = jnp.einsum("bsc,ce->bse", xi, p["w_bc"]).astype(jnp.float32)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                       # (B,S,N)
    dt = jax.nn.softplus(
        jnp.einsum("bsc,co->bso", xi, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                  # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di,N)

    log_decay = dt[..., None] * A[None, None]                 # (B,S,di,N) <=0
    drive = (dt[..., None] * Bm[:, :, None, :]
             * xi.astype(jnp.float32)[..., None])             # (B,S,di,N)

    if state is None:
        c = min(chunk, S)
        if S % c:
            c = S  # fall back: small odd sequences
        ldc = _split_chunks(log_decay, c)                     # (nc,B,c,di,N)
        drc = _split_chunks(drive, c)
        cmc = _split_chunks(Cm, c)

        def chunk_step(h_in, inp):
            ld, dr, cm = inp
            def combine(a, b):
                return (a[0] + b[0], b[1] + a[1] * jnp.exp(b[0]))
            cum_ld, h_local = jax.lax.associative_scan(combine, (ld, dr),
                                                       axis=1)
            h = h_local + jnp.exp(cum_ld) * h_in[:, None]
            y = jnp.einsum("bscn,bsn->bsc", h, cm)
            return h[:, -1], y

        h0 = jnp.zeros((B,) + log_decay.shape[2:], jnp.float32)
        new_h, yc = jax.lax.scan(chunk_step, h0, (ldc, drc, cmc))
        y = _merge_chunks(yc)
    else:
        h_prev, _ = state
        h = jnp.exp(log_decay[:, 0]) * h_prev + drive[:, 0]
        y = jnp.einsum("bcn,bn->bc", h, Cm[:, 0])[:, None]
        new_h = h

    y = y + p["D_skip"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsc,cd->bsd", y, p["w_out"])
    return out, (new_h, conv_tail)


def mamba_init_state(p, batch, dtype=jnp.float32):
    di, N = p["A_log"].shape
    K = p["conv_w"].shape[0]
    return (jnp.zeros((batch, di, N), jnp.float32),
            jnp.zeros((batch, K - 1, di), dtype))


# --------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunkwise form) + sLSTM (sequential)
# --------------------------------------------------------------------------

def init_mlstm(key, d_model, n_heads, expand=2, dtype=jnp.float32):
    di = expand * d_model
    dh = di // n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": _dense_init(ks[0], (d_model, 2 * di), d_model, dtype),
        "wq": _dense_init(ks[1], (di, n_heads, dh), di, dtype),
        "wk": _dense_init(ks[2], (di, n_heads, dh), di, dtype),
        "wv": _dense_init(ks[3], (di, n_heads, dh), di, dtype),
        "w_if": _dense_init(ks[4], (di, 2 * n_heads), di, jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((n_heads,), jnp.float32),
                                    jnp.full((n_heads,), 3.0, jnp.float32)]),
        "out_norm": init_norm(dh, dtype=jnp.float32),
        "w_down": _dense_init(ks[5], (di, d_model), di, dtype),
    }


def mlstm_apply(p, x, state=None, chunk=_CHUNK):
    """Stabilised mLSTM.  Chunkwise scan for sequences; O(1) decode.

    Chunk math (per head): carry (C, n, m̃).  Within a chunk,
      intra: D_ij = exp(F_i - F_j + i_j - m_i), j <= i   (F = cum log f)
      inter: q_i reads carried C with decay exp(F_i + m̃ - m_i)
      state: C' = exp(F_tot + m̃ - m̃')·C + Σ_t exp(F_tot - F_t + i_t - m̃')·k v
    Returns (y, new_state)."""
    B, S, D = x.shape
    H, dh = p["wq"].shape[1], p["wq"].shape[2]
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xi, p["wq"]) / math.sqrt(dh)
    k = jnp.einsum("bse,ehk->bshk", xi, p["wk"]) / math.sqrt(dh)
    v = jnp.einsum("bse,ehk->bshk", xi, p["wv"])
    gates = (jnp.einsum("bse,eg->bsg", xi.astype(jnp.float32), p["w_if"])
             + p["if_bias"])
    ig, fg = jnp.split(gates, 2, axis=-1)                     # (B,S,H)
    log_f = -jax.nn.softplus(-fg)

    if state is None:
        st = mlstm_init_state_b(B, H, dh)
    else:
        st = state

    if S == 1 and state is not None:
        C_prev, n_prev, m_prev = st
        lf, ii = log_f[:, 0], ig[:, 0]
        m_new = jnp.maximum(lf + m_prev, ii)
        fsc = jnp.exp(lf + m_prev - m_new)
        isc = jnp.exp(ii - m_new)
        qf = q[:, 0].astype(jnp.float32)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        C = fsc[..., None, None] * C_prev + isc[..., None, None] * \
            jnp.einsum("bhk,bhd->bhkd", kf, vf)
        n = fsc[..., None] * n_prev + isc[..., None] * kf
        num = jnp.einsum("bhk,bhkd->bhd", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]
        new_state = (C, n, m_new)
    else:
        c = min(chunk, S)
        if S % c:
            c = S
        qc, kc, vc = (_split_chunks(t, c) for t in (q, k, v))
        lfc, igc = _split_chunks(log_f, c), _split_chunks(ig, c)

        def chunk_step(carry, inp):
            Cst, nst, mst = carry
            qb, kb, vb, lf, ii = inp
            qb = qb.astype(jnp.float32); kb = kb.astype(jnp.float32)
            vb = vb.astype(jnp.float32)
            F = jnp.cumsum(lf, axis=1)                        # (B,c,H)
            # stabiliser per query position
            intra_log = (F[:, :, None] - F[:, None, :]
                         + ii[:, None, :, :])                 # (B,cq,ck,H)
            causal = jnp.tril(jnp.ones((c, c), jnp.bool_))
            intra_log = jnp.where(causal[None, :, :, None], intra_log,
                                  -jnp.inf)
            inter_log = F + mst[:, None]                      # (B,c,H)
            m_i = jnp.maximum(jax.lax.stop_gradient(intra_log).max(2),
                              jax.lax.stop_gradient(inter_log))
            m_i = jnp.maximum(m_i, 0.0)
            dintra = jnp.exp(intra_log - m_i[:, :, None])
            dinter = jnp.exp(inter_log - m_i)                 # (B,c,H)
            scores = jnp.einsum("bqhx,bkhx->bqkh", qb, kb)
            wmat = scores * dintra
            y_intra = jnp.einsum("bqkh,bkhd->bqhd", wmat, vb)
            y_inter = jnp.einsum("bqhk,bhkd->bqhd", qb, Cst) \
                * dinter[..., None]
            # denominator: q·n with n_q = Σ_j dintra[q,j]·k_j + dinter·n_st,
            # so q·n = Σ_j wmat[q,j] + dinter·(q·n_st)
            den_intra = wmat.sum(2)

            den_inter = jnp.einsum("bqhk,bhk->bqh", qb, nst) * dinter
            den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_i))
            y = (y_intra + y_inter) / den[..., None]

            # state update
            F_tot = F[:, -1]                                  # (B,H)
            m_up = jnp.maximum(F_tot + mst,
                               (F_tot[:, None] - F + ii).max(1))
            sc_old = jnp.exp(F_tot + mst - m_up)
            sc_tok = jnp.exp(F_tot[:, None] - F + ii - m_up[:, None])
            C_new = sc_old[..., None, None] * Cst + jnp.einsum(
                "bkh,bkhx,bkhd->bhxd", sc_tok, kb, vb)
            n_new = sc_old[..., None] * nst + jnp.einsum(
                "bkh,bkhx->bhx", sc_tok, kb)
            return (C_new, n_new, m_up), y

        (Cst, nst, mst), yc = jax.lax.scan(chunk_step, st,
                                           (qc, kc, vc, lfc, igc))
        y = _merge_chunks(yc)
        new_state = (Cst, nst, mst)

    y = norm_apply(p["out_norm"], y.astype(x.dtype))
    y = y.reshape(B, S, -1) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["w_down"]), new_state


def mlstm_init_state_b(batch, H, dh):
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.zeros((batch, H), jnp.float32))


def mlstm_init_state(p, batch):
    H, dh = p["wq"].shape[1], p["wq"].shape[2]
    return mlstm_init_state_b(batch, H, dh)


def init_slstm(key, d_model, n_heads, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gates": _dense_init(ks[0], (d_model, 4 * d_model), d_model, dtype),
        "r_gates": _dense_init(ks[1], (d_model, 4 * d_model), d_model, dtype),
        "g_bias": jnp.zeros((4 * d_model,), jnp.float32),
        "out_norm": init_norm(d_model, dtype=jnp.float32),
        "w_down": _dense_init(ks[2], (d_model, d_model), d_model, dtype),
    }


def slstm_apply(p, x, state=None):
    """sLSTM with exponential gating — a true recurrence through h (the
    hidden-to-gate feedback makes it inherently sequential; lax.scan)."""
    B, S, D = x.shape
    wx = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32),
                    p["w_gates"].astype(jnp.float32)) + p["g_bias"]
    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = (z, z, z, z)
    c0, n0, h0, m0 = state
    R = p["r_gates"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, h, m = carry
        g = wx_t + h @ R
        zt, it, ft, ot = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zt)
        lf = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(lf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    y = norm_apply(p["out_norm"], y)
    return jnp.einsum("bsd,de->bse", y, p["w_down"]), (c, n, h, m)


def slstm_init_state(p, batch):
    D = p["w_down"].shape[0]
    z = jnp.zeros((batch, D), jnp.float32)
    return (z, z, z, z)
