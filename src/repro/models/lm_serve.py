"""Batched LM serving engine: prefill + jit'd decode over KV caches.

Lives under :mod:`repro.models` because it is model-side scaffolding — the
token sampler and the fixed-batch generate loop the LM/whisper substrate
tests exercise.  (``repro.serve`` is the *stencil* serving subsystem; the
name ``ServeEngine`` is kept for the LM engine so substrate callers read
naturally.)

Local (SWA) layers hold ring-buffer caches (length = window) — the sequence
shift buffer — so decode state is bounded regardless of generation length;
global layers hold full caches up to ``max_len``.  Requests are served in
fixed batches (continuous batching hooks: ``add_request`` queues, a slot
becomes free when a sequence emits EOS or hits its token budget).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .transformer import decode_step, prefill


def sample_token(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@dataclasses.dataclass
class LMServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch: int, max_len: int,
                 temperature: float = 0.0, eos: int = -1):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self.temperature, self.eos = temperature, eos
        self.stats = LMServeStats()

        def _decode(params, cache, tokens, pos, key):
            logits, cache = decode_step(cfg, params, cache, tokens, pos)
            nxt = sample_token(logits, key, temperature)
            return nxt, logits, cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._prefill = jax.jit(
            functools.partial(prefill, cfg, max_len=max_len))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 seed: int = 0):
        """prompts: (B, S) int32 (right-aligned, padded with 0 on the left is
        the caller's concern — fixed-shape serving).  Returns (B, new) ids."""
        B, S = prompts.shape
        assert B == self.batch
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        self.stats.prefill_tokens += B * S
        key = jax.random.PRNGKey(seed)
        tok = sample_token(logits, key, self.temperature)
        out = [tok]
        done = (tok == self.eos)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            tok, logits, cache = self._decode(self.params, cache, tok,
                                              jnp.int32(S + i), sub)
            out.append(tok)
            self.stats.decode_tokens += B
            done = done | (tok == self.eos)
            if bool(done.all()):
                break
        return np.stack([np.asarray(t) for t in out], axis=1)
