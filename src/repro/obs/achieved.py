"""Roofline-achieved instrumentation: measured performance over the model.

The roofline model (:func:`repro.analysis.stencil_roofline.model_plan`)
predicts seconds per time step for a plan's exact geometry; nothing in the
stack ever compared that prediction against reality (ROADMAP item 3).
This module is the bridge: wrap any compiled executor, measure it with the
same warm-up + best-of-k discipline as the tuner, and report

    achieved_fraction = modeled_seconds / measured_seconds

i.e. achieved performance as a fraction of the model's prediction (> 1
means the run beat the model — expected in interpret mode on CPU where
the model prices TPU hardware, the *trend* per commit is the observable).
The fraction rides on tune records (``record["roofline_fraction"]``),
:class:`~repro.obs.events.PlanChosen` events, and the smoke-benchmark
rows that ROADMAP item 3's regression gate reads.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class AchievedResult:
    """One measured-vs-modeled comparison for a compiled executor."""

    measured_s: float         # best-of-k wall seconds for one call
    modeled_s: float          # model_plan prediction for the same call
    steps: int                # time steps one call advances (1 = single)
    points: float             # grid points per step
    bytes_moved: float        # modeled HBM bytes for the whole call
    achieved_fraction: float  # modeled_s / measured_s, in (0, inf)

    @property
    def steps_per_sec(self) -> float:
        return self.steps / self.measured_s if self.measured_s > 0 else 0.0

    @property
    def gbytes_per_sec(self) -> float:
        return (self.bytes_moved / self.measured_s / 1e9
                if self.measured_s > 0 else 0.0)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["steps_per_sec"] = self.steps_per_sec
        return d


def achieved_fraction(modeled_s: float, measured_s: float) -> float:
    """``modeled / measured`` with degenerate timings clamped out of the
    (0, inf) acceptance interval's edges rather than raising mid-benchmark."""
    if measured_s <= 0 or modeled_s <= 0:
        return 0.0
    return modeled_s / measured_s


def model_call_seconds(ex) -> float:
    """The roofline prediction for ONE call of a compiled executor: the
    per-step :func:`~repro.analysis.stencil_roofline.model_plan` price of
    its plan (on the shard-local grid when sharded — shards run in
    parallel) times the steps a call advances."""
    from ..analysis.stencil_roofline import model_plan
    grid = ex.grid
    if getattr(ex, "shard", None) is not None:
        grid = ex.shard.local_grid
    steps = ex.time_spec.steps if getattr(ex, "time_spec", None) else 1
    return model_plan(ex.program, ex.plan, grid) * steps


def fraction_for(ex, measured_s: float) -> float:
    """``achieved_fraction`` for an executor somebody else already timed
    (the benchmark rows' path — no second measurement)."""
    return achieved_fraction(model_call_seconds(ex), measured_s)


def measure_achieved(ex, fields, scalars=None, coeffs=None, *,
                     warmup: int = 1, repeats: int = 3,
                     timer=None, tracer=None) -> AchievedResult:
    """Measure ``ex`` (warm-up + best-of-k ``block_until_ready``) and
    compare against its roofline prediction.

    ``timer(fn) -> seconds`` is injectable exactly like
    :class:`~repro.core.tune.TuneConfig.timer`; ``tracer`` (default: the
    ambient one) gets a ``roofline.achieved`` span carrying the result."""
    import jax

    from .trace import current_tracer
    tracer = tracer or current_tracer()
    fields = dict(fields)
    scalars = dict(scalars or {})
    coeffs = dict(coeffs or {})

    def call():
        return ex(fields, scalars, coeffs)

    if timer is None:
        def timer(fn):
            out = None
            for _ in range(max(1, warmup)):
                out = fn()
            jax.block_until_ready(out)
            best = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                out = fn()
                jax.block_until_ready(out)
                best = min(best, time.perf_counter() - t0)
            return best

    with tracer.span("roofline.achieved", program=ex.program.name,
                     backend=ex.plan.backend,
                     schedule=getattr(ex.plan, "schedule", "block")) as sp:
        measured = float(timer(call))
        steps = ex.time_spec.steps if getattr(ex, "time_spec", None) else 1
        modeled = model_call_seconds(ex)
        points = float(np.prod([int(g) for g in ex.grid]))
        from ..analysis.stencil_roofline import plan_bytes_per_point
        bpp = plan_bytes_per_point(ex.program, ex.plan, ex.grid)
        res = AchievedResult(
            measured_s=measured, modeled_s=modeled, steps=int(steps),
            points=points, bytes_moved=bpp * points * int(steps),
            achieved_fraction=achieved_fraction(modeled, measured))
        sp.set(measured_s=measured, modeled_s=modeled,
               steps=int(steps), roofline_fraction=res.achieved_fraction)
    return res
