"""Structured event tracing — the observability substrate every layer
emits into.

A :class:`Tracer` records two record kinds:

* **spans** — nested, wall-clock-timed intervals opened with
  ``tracer.span("compile")`` (a context manager; attach attributes at open
  time or later via ``sp.set(...)``).  Nesting is per-thread: the compile
  pipeline, the tuner's candidate loop and the serving worker each build
  their own stack.
* **events** — instant, typed occurrences: ``tracer.event("name", k=v)``
  or ``tracer.emit(PlanChosen(...))`` for the typed payloads in
  :mod:`repro.obs.events`.

Everything is **off by default and near-zero cost when off**: the ambient
tracer (:func:`current_tracer`) is a process-wide no-op singleton
(:data:`NULL`) unless a real tracer was installed — explicitly
(:func:`set_tracer` / ``Tracer.active()`` / ``CompileOptions(trace=...)``
/ ``StencilEngine(tracer=...)``) or via the ``REPRO_TRACE=path``
environment variable, which installs a process tracer whose records are
exported to ``path`` at interpreter exit (Chrome ``trace_event`` JSON, or
JSONL when the path ends in ``.jsonl``).  No emission point sits inside
jitted code — tracing never touches numerics, so disabling it is
bit-identical by construction.

Exports:

* :meth:`Tracer.export_jsonl` — one JSON record per line (machine grep).
* :meth:`Tracer.export_chrome` — Chrome ``trace_event`` format, loadable
  in ``chrome://tracing`` / Perfetto: spans are ``ph="X"`` complete events
  (``ts``/``dur`` in microseconds), instants are ``ph="i"``.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time

#: Environment variable: set to a path to trace the whole process and
#: export at exit (Chrome trace_event JSON; ``*.jsonl`` for JSONL).
TRACE_ENV = "REPRO_TRACE"


class _Span:
    """One open interval; closes (and records itself) on ``__exit__``."""

    __slots__ = ("_tracer", "name", "t0", "args", "depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.depth = 0

    def set(self, **attrs) -> "_Span":
        """Attach attributes to the span (visible in both export formats)."""
        self.args.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        """Emit an instant event while this span is open."""
        self._tracer.event(name, **attrs)

    def __enter__(self) -> "_Span":
        self.t0 = self._tracer._clock()
        stack = self._tracer._stack()
        self.depth = len(stack)
        stack.append(self)
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer._clock()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record({
            "kind": "span", "name": self.name, "ts": self.t0,
            "dur": max(0.0, t1 - self.t0), "depth": self.depth,
            "args": self.args,
        })
        return False


class _NullSpan:
    """Reusable no-op span: the entire disabled-tracing cost is one method
    call returning this shared object (no allocation, no clock reads)."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Process-wide span/event recorder.  Thread-safe: records append under
    a lock, span nesting uses a per-thread stack, and every record carries
    ``pid`` plus a small per-thread ``tid`` so exports separate tracks."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self._records: list = []
        self._local = threading.local()
        self._tids: dict = {}
        self.epoch = clock()
        self.epoch_unix = time.time()

    # -- recording -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return True

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            return self._tids.setdefault(ident, len(self._tids))

    def _record(self, rec: dict) -> None:
        rec["ts"] = rec["ts"] - self.epoch
        rec["pid"] = os.getpid()
        rec["tid"] = self._tid()
        with self._lock:
            self._records.append(rec)

    def span(self, name: str, **attrs) -> _Span:
        """Open a nested, timed span (use as a context manager)."""
        return _Span(self, name, dict(attrs))

    def event(self, name: str, **attrs) -> None:
        """Record an instant event at the current time/thread/depth."""
        self._record({"kind": "event", "name": name, "ts": self._clock(),
                      "depth": len(self._stack()), "args": attrs})

    def emit(self, ev) -> None:
        """Record a typed event (any dataclass from :mod:`repro.obs.events`
        — the class name becomes the event name, fields the args)."""
        import dataclasses
        self.event(type(ev).__name__, **dataclasses.asdict(ev))

    # -- reading -------------------------------------------------------
    def records(self, kind: str | None = None, name: str | None = None
                ) -> list:
        """Snapshot of recorded spans/events (filtered copies)."""
        with self._lock:
            recs = list(self._records)
        if kind is not None:
            recs = [r for r in recs if r["kind"] == kind]
        if name is not None:
            recs = [r for r in recs if r["name"] == name]
        return recs

    def spans(self, name: str | None = None) -> list:
        return self.records(kind="span", name=name)

    def events(self, name: str | None = None) -> list:
        return self.records(kind="event", name=name)

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    # -- ambient installation ------------------------------------------
    def active(self):
        """Context manager installing this tracer as the thread-ambient
        :func:`current_tracer` (restores the previous one on exit).  This
        is how the compile pipeline threads an explicit
        ``CompileOptions(trace=...)`` down through layers whose functions
        never see a tracer argument."""
        return _Active(self)

    # -- export --------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """One JSON record per line; returns the record count."""
        recs = self.records()
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
        return len(recs)

    def export_chrome(self, path: str) -> int:
        """Chrome ``trace_event`` JSON (``chrome://tracing`` / Perfetto).

        Spans become ``ph="X"`` complete events with microsecond
        ``ts``/``dur``; instant events become ``ph="i"``.  Returns the
        event count written."""
        out = []
        for r in self.records():
            base = {"name": r["name"], "pid": r["pid"], "tid": r["tid"],
                    "ts": r["ts"] * 1e6, "cat": r["kind"],
                    "args": r.get("args", {})}
            if r["kind"] == "span":
                base["ph"] = "X"
                base["dur"] = r["dur"] * 1e6
            else:
                base["ph"] = "i"
                base["s"] = "t"
            out.append(base)
        doc = {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs",
                          "epoch_unix": self.epoch_unix},
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return len(out)


class _Active:
    __slots__ = ("_tracer", "_prev")

    def __init__(self, tracer):
        self._tracer = tracer

    def __enter__(self):
        self._prev = getattr(_ambient, "tracer", None)
        _ambient.tracer = self._tracer
        return self._tracer

    def __exit__(self, *exc):
        _ambient.tracer = self._prev
        return False


class NullTracer(Tracer):
    """The disabled tracer: every method is a no-op (spans return one
    shared reusable object), so instrumented code pays a single dynamic
    dispatch per emission point and allocates nothing."""

    def __init__(self):  # no lock, no buffers
        self.epoch = 0.0
        self.epoch_unix = 0.0

    @property
    def enabled(self) -> bool:
        return False

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, **attrs) -> None:
        pass

    def emit(self, ev) -> None:
        pass

    def records(self, kind=None, name=None) -> list:
        return []

    def clear(self) -> None:
        pass

    def active(self):
        return _Active(self)

    def export_jsonl(self, path: str) -> int:
        raise RuntimeError("cannot export the no-op tracer; install a real "
                           "Tracer (set_tracer / CompileOptions(trace=...) "
                           f"/ {TRACE_ENV}=path)")

    export_chrome = export_jsonl


#: The process-wide no-op singleton — what :func:`current_tracer` returns
#: when tracing is off.
NULL = NullTracer()

_ambient = threading.local()
_global: Tracer | None = None
_env_checked = False
_lock = threading.Lock()


def set_tracer(tracer: Tracer | None) -> None:
    """Install (or, with ``None``, remove) the process-global tracer."""
    global _global
    _global = tracer


def _tracer_from_env() -> Tracer | None:
    """``REPRO_TRACE=path``: build a process tracer that exports to
    ``path`` at interpreter exit.  Checked once per process (call
    :func:`_reset_for_tests` to re-read)."""
    global _env_checked, _global
    with _lock:
        if _env_checked:
            return _global
        _env_checked = True
        path = os.environ.get(TRACE_ENV)
        if not path or _global is not None:
            return _global
        tracer = Tracer()
        _global = tracer

        def _export():
            try:
                if path.endswith(".jsonl"):
                    tracer.export_jsonl(path)
                else:
                    tracer.export_chrome(path)
            except OSError:  # pragma: no cover - exit-time best effort
                pass

        atexit.register(_export)
        return _global


def current_tracer() -> Tracer:
    """The ambient tracer: a thread-local override installed by
    ``Tracer.active()`` wins, else the process-global tracer
    (:func:`set_tracer` or ``REPRO_TRACE``), else :data:`NULL`."""
    t = getattr(_ambient, "tracer", None)
    if t is not None:
        return t
    g = _global if _env_checked else _tracer_from_env()
    return g if g is not None else NULL


def resolve_tracer(trace) -> Tracer:
    """Normalise a user-facing ``trace=`` knob: ``None``/``False`` defer to
    :func:`current_tracer` (the ambient/no-op default), ``True`` installs
    and returns a fresh process tracer, a :class:`Tracer` is itself."""
    if trace is None or trace is False:
        return current_tracer()
    if trace is True:
        t = current_tracer()
        if t is NULL:
            t = Tracer()
            set_tracer(t)
        return t
    if isinstance(trace, Tracer):
        return trace
    raise TypeError(f"trace= must be a Tracer, True, or None; got "
                    f"{type(trace).__name__}")


def _reset_for_tests() -> None:
    """Drop global/env tracer state (tests re-reading ``REPRO_TRACE``)."""
    global _global, _env_checked
    with _lock:
        _global = None
        _env_checked = False
    _ambient.tracer = None
