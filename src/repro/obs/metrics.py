"""Metrics registry — counters, gauges and histograms with a JSON-ready
``snapshot()``.

One :class:`MetricsRegistry` is a namespace of named instruments.
:class:`~repro.serve.stats.ServeStats` is the serve-scoped view over one
(its public attribute API is unchanged); compile-side code increments the
process-global registry (:func:`global_metrics`): compiles, lowerings,
tune runs, timed measurements, and plan-cache hit/miss counts (mirrored
from each :class:`~repro.core.tune.PlanCache`'s own registry so one
snapshot shows process-wide rates).

Everything is plain-int/float mutation — the engine's single-writer
threading model and the compile path's GIL-held bookkeeping need no
atomics — and ``snapshot()`` returns only JSON-serialisable scalars, so a
snapshot round-trips through ``json.dumps``/``loads`` unchanged.
"""

from __future__ import annotations

import collections


class Counter:
    """Monotonic-by-convention integer (``inc``), settable for views that
    mirror externally-tracked values."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += int(n)
        return self.value

    def set(self, v: int) -> None:
        self.value = int(v)

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """A float that goes up and down (wall-clock accumulators, ratios)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> float:
        self.value += float(v)
        return self.value

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Bounded sample reservoir with exact quantiles over the retained
    window (a ``deque(maxlen=...)`` — steady-state tails, not all-time)."""

    __slots__ = ("name", "samples", "total")

    def __init__(self, name: str, maxlen: int = 4096):
        self.name = name
        self.samples: collections.deque = collections.deque(maxlen=maxlen)
        self.total = 0            # observations ever (beyond the window)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))
        self.total += 1

    def __len__(self) -> int:
        return len(self.samples)

    def clear(self) -> None:
        """Drop the retained window (``total`` keeps counting ever-seen)."""
        self.samples.clear()

    def quantile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[i]

    def snapshot(self) -> dict:
        return {"count": len(self.samples), "total": self.total,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named instruments, get-or-create by kind.  Re-requesting a name with
    a different kind is a bug and raises rather than silently aliasing."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, kind, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, **kw)
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, maxlen: int = 4096) -> Histogram:
        return self._get(name, Histogram, maxlen=maxlen)

    def names(self) -> list:
        return sorted(self._metrics)

    def reset(self) -> None:
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                m.samples.clear()
                m.total = 0
            else:
                m.reset()

    def snapshot(self) -> dict:
        """Flat JSON-serialisable view: counters/gauges map to their value,
        histograms to ``{count, total, p50, p99}``."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            out[name] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out


_GLOBAL = MetricsRegistry()


def global_metrics() -> MetricsRegistry:
    """The process-wide registry compile-side counters accumulate into."""
    return _GLOBAL
