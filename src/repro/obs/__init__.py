"""repro.obs — the observability subsystem (tracing, metrics, achieved
roofline).

Three pillars, all dependency-free and off by default:

* **tracing** (:mod:`repro.obs.trace` + :mod:`repro.obs.events`): nested
  wall-clock spans and typed events emitted from every layer of the stack
  (compile, dataflow legalisation, tuner, distribution, serving), exported
  to JSONL or Chrome ``trace_event`` JSON.  Enable with
  ``CompileOptions(trace=tracer)``, ``StencilEngine(tracer=...)``,
  ``set_tracer``, or ``REPRO_TRACE=path``.
* **metrics** (:mod:`repro.obs.metrics`): counters/gauges/histograms with
  a JSON-ready ``snapshot()``; ``ServeStats`` is the serve-scoped view,
  :func:`global_metrics` collects the compile side.
* **achieved roofline** (:mod:`repro.obs.achieved`): measured performance
  as a fraction of :func:`~repro.analysis.stencil_roofline.model_plan`'s
  prediction — ROADMAP item 3's tracked quantity.

``achieved`` imports the analysis/core layers, which themselves emit into
``trace``/``metrics`` — it loads lazily here so those layers can import
``repro.obs`` without a cycle.
"""

from .events import (CacheHit, CacheMiss, ChainDemoted, ExecutorEvicted,
                     PlanChosen, PlaneDemoted)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      global_metrics)
from .trace import (NULL, TRACE_ENV, NullTracer, Tracer, current_tracer,
                    resolve_tracer, set_tracer)

__all__ = [
    "CacheHit", "CacheMiss", "ChainDemoted", "ExecutorEvicted",
    "PlanChosen", "PlaneDemoted",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "global_metrics",
    "NULL", "TRACE_ENV", "NullTracer", "Tracer", "current_tracer",
    "resolve_tracer", "set_tracer",
    "AchievedResult", "achieved_fraction", "fraction_for",
    "measure_achieved", "model_call_seconds",
]

_ACHIEVED = ("AchievedResult", "achieved_fraction", "fraction_for",
             "measure_achieved", "model_call_seconds")


def __getattr__(name: str):
    if name in _ACHIEVED:
        from . import achieved
        return getattr(achieved, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
