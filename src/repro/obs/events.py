"""Typed trace events — the vocabulary of decisions the stack narrates.

Each event is a small frozen dataclass; ``tracer.emit(ev)`` records it
under the class name with the fields as args, so exports (JSONL, Chrome
``trace_event``) carry machine-readable payloads and tests can assert on
specific decisions instead of log strings.

The set mirrors the silent decisions the optimiser used to bury in field
values:

* :class:`PlanChosen` — a compile or tune settled on a plan (with the
  modeled-vs-measured ``roofline_fraction`` when a measurement exists);
* :class:`ChainDemoted` / :class:`PlaneDemoted` — stream legalisation
  reduced a requested ``time_tile`` / ``plane_tile`` (the structured form
  of ``chain_split_reason`` / ``plane_split_reason``);
* :class:`CacheHit` / :class:`CacheMiss` — any reuse layer consulted
  (``cache`` names which: ``"tuned_plan"``, ``"serve_record"``,
  ``"executor"``);
* :class:`ExecutorEvicted` — the serving LRU dropped a compiled bucket.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PlanChosen:
    """A plan was settled on — by the heuristic, the tuner, or a cache.

    ``roofline_fraction`` is achieved/predicted performance
    (``modeled_s / measured_s``; > 1 means the run beat the model) and is
    ``None`` when nothing was measured (pure-heuristic compiles)."""

    program: str
    backend: str
    schedule: str
    strategy: str
    label: str = ""
    time_tile: int = 1
    plane_tile: int = 1
    modeled_us: float | None = None
    measured_us: float | None = None
    roofline_fraction: float | None = None


@dataclasses.dataclass(frozen=True)
class ChainDemoted:
    """Temporal blocking: the requested ``time_tile`` could not chain."""

    program: str
    requested: int
    effective: int
    reason: str


@dataclasses.dataclass(frozen=True)
class PlaneDemoted:
    """Spatial unrolling: the requested ``plane_tile`` could not widen."""

    program: str
    requested: int
    effective: int
    reason: str


@dataclasses.dataclass(frozen=True)
class CacheHit:
    cache: str            # which reuse layer: tuned_plan / serve_record / ...
    key: str


@dataclasses.dataclass(frozen=True)
class CacheMiss:
    cache: str
    key: str


@dataclasses.dataclass(frozen=True)
class ExecutorEvicted:
    """The serving engine's LRU cap dropped a compiled bucket executor."""

    key: str
    resident: int         # executors still resident after the eviction
