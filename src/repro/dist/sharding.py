"""Sharding rules: one declarative object mapping model state onto the mesh.

``ShardingRules`` names the mesh axes each parallelism style uses:

* ``tp``   — tensor parallelism axis (Megatron-style weight sharding)
* ``fsdp`` — fully-sharded data parallel axis(es) for parameter storage
* ``dp``   — pure data-parallel axes (batch dimension)
* ``seq_sharding`` — Megatron-SP: shard the sequence dim of activations
* ``kv_seq_shard`` — flash-decoding: shard KV caches over the length dim

Spec assignment is *shape-driven* and divisibility-guarded so any config /
mesh combination lowers: a dimension is only sharded when the mesh axis
divides it; everything else stays replicated.  Activation constraints are
installed via :func:`activation_context` (a contextvar, so jit-traced model
code calls :func:`shard_activation` unconditionally and it no-ops outside a
context — single-host tests never touch device state).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def make_auto_mesh(shape, axes, **kwargs):
    """``jax.make_mesh`` with Auto axis types where the API supports them
    (jax >= 0.5); older releases treat every axis as auto implicitly."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def _as_tuple(axes) -> tuple:
    if axes is None:
        return ()
    if isinstance(axes, (tuple, list)):
        return tuple(a for a in axes if a)
    return (axes,)


@dataclasses.dataclass
class ShardingRules:
    mesh: object
    tp: str | None = None
    fsdp: object = None          # str | tuple | None
    dp: tuple = ()
    seq_sharding: bool = False
    kv_seq_shard: bool = False

    def batch_axes(self) -> tuple:
        return _as_tuple(self.dp)

    def fsdp_axes(self) -> tuple:
        return _as_tuple(self.fsdp)

    def axis_size(self, axes) -> int:
        n = 1
        for a in _as_tuple(axes):
            n *= self.mesh.shape[a]
        return n


def _divides(rules: ShardingRules, axes, dim: int) -> bool:
    axes = _as_tuple(axes)
    return bool(axes) and dim % rules.axis_size(axes) == 0


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def _param_spec(shape, rules: ShardingRules) -> P:
    """TP on the innermost divisible matmul dim, FSDP on the largest
    remaining one.  Rank<2 leaves (norm scales, counts) stay replicated."""
    if len(shape) < 2:
        return P()
    entries: list = [None] * len(shape)
    if rules.tp is not None:
        for ax in (len(shape) - 1, len(shape) - 2):
            if _divides(rules, rules.tp, shape[ax]):
                entries[ax] = rules.tp
                break
    fs = rules.fsdp_axes()
    if fs:
        free = [ax for ax in range(len(shape)) if entries[ax] is None]
        free.sort(key=lambda ax: -shape[ax])
        for ax in free:
            if _divides(rules, fs, shape[ax]):
                entries[ax] = fs if len(fs) > 1 else fs[0]
                break
    return P(*entries)


def param_specs(cfg, pshapes, rules: ShardingRules):
    """PartitionSpec tree matching the parameter tree structure."""
    return jax.tree.map(lambda s: _param_spec(s.shape, rules), pshapes)


def named_shardings(cfg, params, rules: ShardingRules):
    specs = param_specs(cfg, params, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# --------------------------------------------------------------------------
# KV caches
# --------------------------------------------------------------------------

def _cache_spec(shape, rules: ShardingRules) -> P:
    """(B, L, H, dh)-shaped entries: batch over dp, heads over tp — or the
    length dim over tp under flash-decoding.  SSM states shard batch only."""
    entries: list = [None] * len(shape)
    if shape and _divides(rules, rules.batch_axes(), shape[0]):
        ba = rules.batch_axes()
        entries[0] = ba if len(ba) > 1 else ba[0]
    if rules.tp is not None and len(shape) >= 3:
        if rules.kv_seq_shard and _divides(rules, rules.tp, shape[1]):
            entries[1] = rules.tp
        elif _divides(rules, rules.tp, shape[-2]):
            entries[-2] = rules.tp
    return P(*entries)


def cache_specs(cfg, cshapes, rules: ShardingRules):
    return jax.tree.map(lambda s: _cache_spec(s.shape, rules), cshapes)


# --------------------------------------------------------------------------
# batches & activations
# --------------------------------------------------------------------------

def batch_sharding(rules: ShardingRules) -> NamedSharding:
    ba = rules.batch_axes()
    spec = P(ba if len(ba) > 1 else (ba[0] if ba else None))
    return NamedSharding(rules.mesh, spec)


_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


@contextlib.contextmanager
def activation_context(rules: ShardingRules | None):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def _activation_spec(shape, kind: str, rules: ShardingRules) -> P | None:
    entries: list = [None] * len(shape)
    changed = False
    if shape and _divides(rules, rules.batch_axes(), shape[0]):
        ba = rules.batch_axes()
        entries[0] = ba if len(ba) > 1 else ba[0]
        changed = True
    if rules.tp is not None:
        if kind == "logits" and shape and _divides(rules, rules.tp, shape[-1]):
            entries[-1] = rules.tp
            changed = True
        elif kind == "residual" and rules.seq_sharding and len(shape) >= 3 \
                and _divides(rules, rules.tp, shape[1]):
            entries[1] = rules.tp       # Megatron-SP: shard the seq dim
            changed = True
        elif kind == "cache" and len(shape) >= 3:
            ax = 1 if rules.kv_seq_shard else len(shape) - 2
            if _divides(rules, rules.tp, shape[ax]):
                entries[ax] = rules.tp
                changed = True
    return P(*entries) if changed else None


def shard_activation(x, kind: str = "residual"):
    """Install a sharding constraint on an activation; no-op outside an
    :func:`activation_context` (so unit tests never need a mesh)."""
    rules = _ACTIVE.get()
    if rules is None or rules.mesh is None:
        return x
    spec = _activation_spec(x.shape, kind, rules)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
