# Distribution substrate: sharding rules shared by the LM stack (train,
# serve, dry-run) and consulted by the stencil distributed executor.
from .sharding import (ShardingRules, activation_context, batch_sharding,
                       cache_specs, make_auto_mesh, named_shardings,
                       param_specs, shard_activation)
