"""Training loop: jit'd step with shardings, grad accumulation, remat,
fault-tolerant driver (resume, async checkpoints, straggler deadline).

``make_train_step`` builds the pjit-ed update; ``Trainer`` owns the
fault-tolerance envelope:

* resume-from-latest on construction (restartability after node failure)
* async checkpoint every ``ckpt_every`` steps, atomic publish
* step-addressable data (no loader state to persist)
* straggler mitigation hook: a per-step wall-clock deadline; steps that
  exceed it are logged and counted (on a real fleet this signals the
  controller to evict/re-slice — here it is observable behaviour under test)
* simulated-failure injection for tests (``fail_at_step``)
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs.base import ModelConfig
from ..dist.sharding import (ShardingRules, activation_context,
                             batch_sharding, named_shardings)
from ..models import init_lm, lm_loss
from .compress import ef_compress_grads, ef_init
from .optimizer import OptConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    microbatches: int = 1            # gradient accumulation factor
    remat: bool = False
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    step_deadline_s: float = 0.0     # 0 = no straggler deadline
    seed: int = 0


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    rules: ShardingRules | None = None):
    """Returns jit'd fn(params, opt_state, batch) -> (params, opt, metrics)."""
    lr_fn = cosine_schedule(tcfg.opt)

    def loss_fn(params, tokens, labels):
        return lm_loss(cfg, params, tokens, labels, remat=tcfg.remat)

    def step_fn(params, opt_state, ef_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if tcfg.microbatches > 1:
            B = tokens.shape[0]
            mb = tcfg.microbatches
            tks = tokens.reshape(mb, B // mb, -1)
            lbs = labels.reshape(mb, B // mb, -1)

            def acc(carry, xs):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, xs[0], xs[1])
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), (tks, lbs))
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels)
        if tcfg.opt.compress_grads:
            grads, ef_state = ef_compress_grads(grads, ef_state)
        params, opt_state, om = adamw_update(tcfg.opt, params, grads,
                                             opt_state, lr_fn)
        out_metrics = {"loss": loss, **om}
        if metrics:
            out_metrics.update(metrics)
        return params, opt_state, ef_state, out_metrics

    if rules is None:
        return jax.jit(step_fn, donate_argnums=(0, 1, 2))

    def wrapped(params, opt_state, ef_state, batch):
        with activation_context(rules):
            return step_fn(params, opt_state, ef_state, batch)

    return jax.jit(wrapped, donate_argnums=(0, 1, 2))


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, data,
                 rules: ShardingRules | None = None,
                 fail_at_step: Optional[int] = None):
        self.cfg, self.tcfg, self.data, self.rules = cfg, tcfg, data, rules
        self.fail_at_step = fail_at_step
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.step_fn = make_train_step(cfg, tcfg, rules)
        self.straggler_events = 0
        self.history: list = []

        key = jax.random.PRNGKey(tcfg.seed)
        params = init_lm(cfg, key)
        opt_state = adamw_init(params)
        ef_state = (ef_init(params) if tcfg.opt.compress_grads
                    else jnp.zeros(()))
        self.state = {"params": params, "opt": opt_state, "ef": ef_state}
        self.step = 0

        last = latest_step(tcfg.ckpt_dir)
        if last is not None:
            shardings = None
            if rules is not None:
                shardings = {"params": named_shardings(cfg, params, rules),
                             "opt": None, "ef": None}
            self.state, extra, self.step = restore_checkpoint(
                tcfg.ckpt_dir, last, self.state,
                shardings if rules else None)
            self.step = int(extra.get("next_step", self.step))

        if rules is not None:
            ps = named_shardings(cfg, self.state["params"], rules)
            self.state["params"] = jax.device_put(self.state["params"], ps)

    def run(self, steps: int):
        try:
            return self._run(steps)
        finally:
            # join the async writer even when a step raises: a checkpoint
            # whose write began before the failure must be durable for the
            # restarted job to resume from it.
            self.ckpt.wait()

    def _run(self, steps: int):
        bs = (batch_sharding(self.rules) if self.rules is not None else None)
        for step in range(self.step, self.step + steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = self.data.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if bs is not None:
                batch = {k: jax.device_put(v, bs) for k, v in batch.items()}
            t0 = time.time()
            (self.state["params"], self.state["opt"], self.state["ef"],
             metrics) = self.step_fn(self.state["params"], self.state["opt"],
                                     self.state["ef"], batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            if self.tcfg.step_deadline_s and dt > self.tcfg.step_deadline_s \
                    and step > self.step:  # first step compiles
                self.straggler_events += 1
            self.history.append({"step": step, "time_s": dt, **metrics})
            if step % self.tcfg.log_every == 0:
                print(f"step {step:6d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms",
                      flush=True)
            nxt = step + 1
            if nxt % self.tcfg.ckpt_every == 0:
                self.ckpt.save(nxt, self.state, {"next_step": nxt})
        self.step = self.step + steps
        return self.history
