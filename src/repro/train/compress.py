"""Error-feedback int8 gradient compression (1-bit-Adam/EF-SGD family).

Gradients are quantised to int8 with a per-tensor scale before the (logical)
all-reduce and dequantised after; the quantisation residual is carried in an
error-feedback buffer so the scheme is unbiased over time.  Under jit the
quantise/dequantise pair marks the reduction operand as int8 — on a real
fabric this shrinks DP all-reduce bytes 4x (f32) / 2x (bf16).  The executor
here demonstrates numerics + the EF invariant; byte savings are claimed in
the roofline analysis, not measured on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g, err):
    """Returns (dequantised gradient, new error) for one leaf."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def ef_compress_grads(grads, err_state):
    out = jax.tree.map(compress_decompress, grads, err_state)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err
