"""AdamW + schedules + gradient utilities, from scratch (no optax).

Moments live in f32 and inherit the parameter sharding (FSDP shards
optimizer state for free).  Includes error-feedback int8 gradient
compression (``compress.py``) as an opt-in distributed-optimization trick.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    compress_grads: bool = False     # int8 error-feedback compression


def cosine_schedule(cfg: OptConfig) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(cfg: OptConfig, params, grads, state, lr_fn=None):
    """Returns (new_params, new_state, metrics)."""
    lr_fn = lr_fn or cosine_schedule(cfg)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = lr_fn(count)
    b1, b2 = cfg.betas

    def upd(p, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
