from .optimizer import (adamw_init, adamw_update, clip_by_global_norm,
                        cosine_schedule, OptConfig)
from .loop import TrainConfig, Trainer, make_train_step
