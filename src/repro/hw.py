"""Target-hardware constants (TPU v5e) used by planners and roofline analysis.

The container executes on CPU; these numbers describe the *target* the plans,
kernels and rooflines are derived for.  Sources: public TPU v5e datasheet
figures as given in the task brief (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    hbm_bytes: int              # HBM capacity per chip
    ici_link_bandwidth: float   # bytes/s per link, per direction
    ici_links: int              # links per chip (2D torus on v5e: 4)
    vmem_bytes: int             # per-core VMEM
    smem_bytes: int             # scalar memory (approximate)
    mxu_shape: tuple = (128, 128)
    sublanes: int = 8
    lanes: int = 128
    # crude power envelope for the paper's Fig.5/6 energy *model* (W per chip)
    busy_watts: float = 200.0
    idle_watts: float = 60.0


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024**2,
    smem_bytes=1024**2,
)

# Budget the stencil planner may claim for windows+outputs inside one kernel
# instance (leave headroom for Mosaic spills and double buffering: the Pallas
# pipeline keeps 2 copies of every block in flight).
VMEM_PLAN_BUDGET = TPU_V5E.vmem_bytes // 4

LANE = TPU_V5E.lanes
SUBLANE = TPU_V5E.sublanes

# field storage dtypes the planner/cost models understand
DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float64": 8}


def align_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def align_down(x: int, m: int) -> int:
    return (x // m) * m
